//! The plan/graph verifier: a pure, side-effect-free pass over a
//! compiled model graph + mapping plan + chip/fleet geometry that checks
//! every invariant the runtime enforces by panicking -- BEFORE a single
//! cell is programmed (on the real chip a bad plan burns write-verify
//! pulses out of finite RRAM endurance).
//!
//! Four entry points, by how much of the world each can see:
//!
//! * [`verify_local`]  -- one chip's slice of a plan (what
//!   `NeuRramChip::program_plan` gates on).  Fleet shards are PARTIAL
//!   plans carrying global replica bookkeeping, so only per-placement
//!   checks run here: window bounds, cell overlap, core range, matrix
//!   presence.
//! * [`verify_model`]  -- a COMPLETE plan for one model (what
//!   `NeuRramChip::program_model` and the fleet's planning step gate
//!   on): local checks plus exact segment coverage, replica
//!   bookkeeping and duplicate layer names.
//! * [`verify_graph`]  -- dataflow invariants of the layer graph
//!   itself, independent of any mapping: stochastic-sampling splits,
//!   ADC bit precisions, residual open/close shape matching.
//! * [`verify_shards`] -- a sharded fleet plan: every global placement
//!   rebased onto exactly one chip, in global order.
//!
//! Each check emits a structured [`Diagnostic`]; [`fail_on_errors`]
//! turns error-severity findings into a [`PlanError`] gate.

use super::diagnostics::{DiagCode, Diagnostic, PlanError, Severity};
use crate::coordinator::mapping::{MappingPlan, SegmentPlacement};
use crate::coordinator::TargetHealth;
use crate::core_sim::Activation;
use crate::models::graph::{LayerKind, ModelGraph};
use crate::models::ConductanceMatrix;
use crate::{CORE_COLS, CORE_WEIGHT_ROWS};
use std::collections::{BTreeMap, BTreeSet};

/// Gate helper: `Err(PlanError)` carrying ALL diagnostics if any has
/// error severity; warnings alone pass.
pub fn fail_on_errors(diags: Vec<Diagnostic>) -> Result<(), PlanError> {
    if diags.iter().any(|d| d.severity == Severity::Error) {
        Err(PlanError::new(diags))
    } else {
        Ok(())
    }
}

/// Per-placement checks valid on ANY plan slice, including fleet shards:
/// E001 (cell overlap), E002 (window bounds), E003 (core range), E004
/// (missing matrix), E005 (segment exceeds its matrix), W102 (matrix
/// with no placement).
pub fn verify_local(
    plan: &MappingPlan,
    matrices: &[ConductanceMatrix],
    num_cores: usize,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (i, p) in plan.placements.iter().enumerate() {
        let s = &p.segment;
        let span = format!("{}[{i}]", s.layer);
        if s.row_hi <= s.row_lo || s.col_hi <= s.col_lo {
            diags.push(Diagnostic::new(
                DiagCode::E002RegionBounds,
                span,
                format!(
                    "degenerate segment window rows [{}, {}) cols [{}, {})",
                    s.row_lo, s.row_hi, s.col_lo, s.col_hi
                ),
            ));
            continue;
        }
        if p.core >= num_cores {
            diags.push(Diagnostic::new(
                DiagCode::E003CoreRange,
                span.clone(),
                format!("targets core {} but the chip has {} cores",
                        p.core, num_cores),
            ));
        }
        if p.core_row_off + s.rows() > CORE_WEIGHT_ROWS
            || p.core_col_off + s.cols() > CORE_COLS
        {
            diags.push(Diagnostic::new(
                DiagCode::E002RegionBounds,
                span.clone(),
                format!(
                    "window ({}+{} pair rows, {}+{} cols) exceeds the \
                     {CORE_WEIGHT_ROWS}x{CORE_COLS} core array",
                    p.core_row_off,
                    s.rows(),
                    p.core_col_off,
                    s.cols()
                ),
            ));
        }
        match matrices.iter().find(|m| m.layer == s.layer) {
            None => diags.push(Diagnostic::new(
                DiagCode::E004MissingMatrix,
                span,
                "no compiled matrix for planned layer",
            )),
            Some(m) => {
                if s.row_hi > m.rows || s.col_hi > m.cols {
                    diags.push(Diagnostic::new(
                        DiagCode::E005SegmentCoverage,
                        span,
                        format!(
                            "segment rows [{}, {}) cols [{}, {}) exceeds \
                             the compiled {}x{} matrix",
                            s.row_lo, s.row_hi, s.col_lo, s.col_hi, m.rows,
                            m.cols
                        ),
                    ));
                }
            }
        }
    }
    // E001: co-resident placements must never share a physical cell
    for (i, a) in plan.placements.iter().enumerate() {
        for (j, b) in plan.placements.iter().enumerate().skip(i + 1) {
            if a.core != b.core || degenerate(a) || degenerate(b) {
                continue;
            }
            let rows_dj = a.phys_rows().end <= b.phys_rows().start
                || b.phys_rows().end <= a.phys_rows().start;
            let cols_dj = a.phys_cols().end <= b.phys_cols().start
                || b.phys_cols().end <= a.phys_cols().start;
            if !rows_dj && !cols_dj {
                diags.push(Diagnostic::new(
                    DiagCode::E001RegionOverlap,
                    format!("{}[{i}] vs {}[{j}]", a.segment.layer,
                            b.segment.layer),
                    format!(
                        "windows overlap on core {}: pair rows {:?}/{:?}, \
                         cols {:?}/{:?}",
                        a.core,
                        a.phys_rows(),
                        b.phys_rows(),
                        a.phys_cols(),
                        b.phys_cols()
                    ),
                ));
            }
        }
    }
    for m in matrices {
        if !plan.placements.iter().any(|p| p.segment.layer == m.layer) {
            diags.push(Diagnostic::new(
                DiagCode::W102UnplacedMatrix,
                m.layer.clone(),
                "compiled matrix has no placement in this plan",
            ));
        }
    }
    diags
}

fn degenerate(p: &SegmentPlacement) -> bool {
    p.segment.row_hi <= p.segment.row_lo || p.segment.col_hi <= p.segment.col_lo
}

/// Whole-model checks on a COMPLETE plan: [`verify_local`] plus exact
/// tiling per replica (E005), replica bookkeeping (E006), duplicate
/// compiled layer names (E008) and replicas sharing a core (W101).
///
/// Do NOT run this on a fleet shard -- shards host a subset of the
/// placements against GLOBAL replica bookkeeping, so coverage and
/// bookkeeping checks would misfire; use [`verify_local`] there.
pub fn verify_model(
    plan: &MappingPlan,
    matrices: &[ConductanceMatrix],
    num_cores: usize,
) -> Vec<Diagnostic> {
    let mut diags = verify_local(plan, matrices, num_cores);
    for (i, m) in matrices.iter().enumerate() {
        if matrices[..i].iter().any(|e| e.layer == m.layer) {
            diags.push(Diagnostic::new(
                DiagCode::E008DuplicateLayer,
                m.layer.clone(),
                "duplicate compiled matrix for layer",
            ));
        }
    }
    // E005: every replica's segments tile its matrix exactly once
    let mut groups: BTreeMap<(&str, usize), Vec<&SegmentPlacement>> =
        BTreeMap::new();
    for p in &plan.placements {
        groups
            .entry((p.segment.layer.as_str(), p.replica))
            .or_default()
            .push(p);
    }
    for ((layer, rep), ps) in &groups {
        let Some(m) = matrices.iter().find(|m| m.layer == *layer) else {
            continue; // E004 already reported
        };
        // segments already flagged degenerate / out of matrix bounds
        // cannot be rasterized meaningfully
        if ps.iter().any(|p| {
            degenerate(p) || p.segment.row_hi > m.rows
                || p.segment.col_hi > m.cols
        }) {
            continue;
        }
        let mut cover = vec![0u8; m.rows * m.cols];
        for p in ps {
            for r in p.segment.row_lo..p.segment.row_hi {
                for c in p.segment.col_lo..p.segment.col_hi {
                    let cell = &mut cover[r * m.cols + c];
                    *cell = cell.saturating_add(1);
                }
            }
        }
        let uncovered = cover.iter().filter(|&&n| n == 0).count();
        let multi = cover.iter().filter(|&&n| n > 1).count();
        if uncovered > 0 || multi > 0 {
            diags.push(Diagnostic::new(
                DiagCode::E005SegmentCoverage,
                format!("{layer} replica {rep}"),
                format!(
                    "segments do not tile the {}x{} matrix exactly once \
                     ({uncovered} cells uncovered, {multi} covered more \
                     than once)",
                    m.rows, m.cols
                ),
            ));
        }
    }
    // E006: declared replica counts must match placed replica indices
    for m in matrices {
        let reps: BTreeSet<usize> = plan
            .placements
            .iter()
            .filter(|p| p.segment.layer == m.layer)
            .map(|p| p.replica)
            .collect();
        if reps.is_empty() {
            continue; // W102 already reported
        }
        let n = reps.len();
        if *reps.iter().next().unwrap() != 0
            || *reps.iter().next_back().unwrap() != n - 1
        {
            diags.push(Diagnostic::new(
                DiagCode::E006ReplicaBookkeeping,
                m.layer.clone(),
                format!("replica indices {reps:?} are not contiguous from 0"),
            ));
        }
        let declared = plan.replica_count(&m.layer);
        if declared != n {
            diags.push(Diagnostic::new(
                DiagCode::E006ReplicaBookkeeping,
                m.layer.clone(),
                format!("plan declares {declared} replicas but {n} distinct \
                         replica indices are placed"),
            ));
        }
    }
    for (l, _) in &plan.replicas {
        if !matrices.iter().any(|m| &m.layer == l) {
            diags.push(Diagnostic::new(
                DiagCode::E006ReplicaBookkeeping,
                l.clone(),
                "replica bookkeeping for a layer with no compiled matrix",
            ));
        }
    }
    // W101: replicas of one layer sharing a core serialize the data
    // parallelism they exist to provide (the packer never does this)
    for m in matrices {
        let mut by_core: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
        for p in plan.placements.iter()
            .filter(|p| p.segment.layer == m.layer)
        {
            by_core.entry(p.core).or_default().insert(p.replica);
        }
        for (core, reps) in &by_core {
            if reps.len() > 1 {
                diags.push(Diagnostic::new(
                    DiagCode::W101ReplicaSharedCore,
                    m.layer.clone(),
                    format!("replicas {reps:?} share core {core}"),
                ));
            }
        }
    }
    diags
}

/// Dataflow invariants of the layer graph itself, independent of any
/// mapping: duplicate names (E008), stochastic sampling on column-split
/// layers (E009), ADC bit precisions and LSTM gate-pair consistency
/// (E010), residual open/close shape matching (E011).
pub fn verify_graph(graph: &ModelGraph) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (i, l) in graph.layers.iter().enumerate() {
        if graph.layers[..i].iter().any(|e| e.name == l.name) {
            diags.push(Diagnostic::new(
                DiagCode::E008DuplicateLayer,
                l.name.clone(),
                "duplicate layer name in graph",
            ));
        }
        if l.activation == Activation::Stochastic
            && l.out_features > CORE_COLS
        {
            diags.push(Diagnostic::new(
                DiagCode::E009StochasticSplit,
                l.name.clone(),
                format!(
                    "stochastic sampling on a column-split layer ({} \
                     outputs > {CORE_COLS} columns): the backward dataflow \
                     must threshold each full pre-activation once, which \
                     per-segment partial sums cannot do",
                    l.out_features
                ),
            ));
        }
        if !(1..=8).contains(&l.input_bits) {
            diags.push(Diagnostic::new(
                DiagCode::E010AdcPrecision,
                l.name.clone(),
                format!("input_bits {} outside the chip's 1..=8 bit-serial \
                         pulse range", l.input_bits),
            ));
        }
        if !(1..=8).contains(&l.output_bits) {
            diags.push(Diagnostic::new(
                DiagCode::E010AdcPrecision,
                l.name.clone(),
                format!("output_bits {} outside the chip's 1..=8 ADC range",
                        l.output_bits),
            ));
        }
    }
    // E010: an LSTM cell's wx/wh gate matrices feed one accumulation,
    // so their pre-activations must share input and ADC precision (the
    // digital LSB alignment assumes it)
    for l in &graph.layers {
        if l.kind != LayerKind::LstmGate {
            continue;
        }
        let Some(prefix) = l.name.strip_suffix(".wx") else { continue };
        let wh_name = format!("{prefix}.wh");
        if let Some(h) = graph.layers.iter().find(|e| e.name == wh_name) {
            if h.input_bits != l.input_bits || h.output_bits != l.output_bits
            {
                diags.push(Diagnostic::new(
                    DiagCode::E010AdcPrecision,
                    l.name.clone(),
                    format!(
                        "LSTM gate pair {}/{} quantized at different \
                         precisions ({}b vs {}b in, {}b vs {}b out)",
                        l.name, wh_name, l.input_bits, h.input_bits,
                        l.output_bits, h.output_bits
                    ),
                ));
            }
        }
    }
    // E011: residual open/close walk, tracking channel and spatial
    // geometry so the close's skip add is shape-compatible with the tap
    let mut hw = graph.input_hw;
    let mut open: Option<(String, usize, usize)> = None;
    for l in &graph.layers {
        if l.kind != LayerKind::Conv {
            if l.res_open || l.res_close {
                diags.push(Diagnostic::new(
                    DiagCode::E011ResidualShape,
                    l.name.clone(),
                    "residual open/close flags on a non-Conv layer are \
                     ignored by the executor",
                ));
            }
            continue;
        }
        if l.res_open {
            if open.is_some() {
                diags.push(Diagnostic::new(
                    DiagCode::E011ResidualShape,
                    l.name.clone(),
                    "res_open while a residual block is already open \
                     (nesting is unsupported)",
                ));
            } else {
                // the executor snapshots this layer's INPUT feature map
                open = Some((l.name.clone(), l.in_channels, hw));
            }
        }
        let out_hw = hw / l.stride.max(1) / l.pool.max(1);
        if l.res_close {
            match open.take() {
                None => diags.push(Diagnostic::new(
                    DiagCode::E011ResidualShape,
                    l.name.clone(),
                    "res_close without a matching res_open",
                )),
                Some((oname, tap_c, tap_hw)) => {
                    if l.out_channels < tap_c {
                        diags.push(Diagnostic::new(
                            DiagCode::E011ResidualShape,
                            l.name.clone(),
                            format!(
                                "close output has {} channels but the tap \
                                 at {oname} carries {tap_c}: the zero-pad \
                                 shortcut cannot shrink channels",
                                l.out_channels
                            ),
                        ));
                    }
                    if out_hw == 0 || tap_hw < out_hw
                        || tap_hw % out_hw != 0
                    {
                        diags.push(Diagnostic::new(
                            DiagCode::E011ResidualShape,
                            l.name.clone(),
                            format!(
                                "tap spatial size {tap_hw} at {oname} is \
                                 not an integer downsample of the close \
                                 output size {out_hw}"
                            ),
                        ));
                    }
                }
            }
        }
        hw = out_hw;
    }
    if let Some((oname, _, _)) = open {
        diags.push(Diagnostic::new(
            DiagCode::E011ResidualShape,
            oname,
            "res_open never closed before the end of the graph",
        ));
    }
    diags
}

/// E007: a sharded fleet plan must cover every global placement exactly
/// once, preserve global order within each shard, and rebase each
/// placement onto chip `core / cores_per_chip` at local core
/// `core % cores_per_chip` without mutating the placement itself.
pub fn verify_shards(
    global: &MappingPlan,
    shards: &[(MappingPlan, Vec<usize>)],
    cores_per_chip: usize,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if cores_per_chip == 0 {
        diags.push(Diagnostic::new(
            DiagCode::E007ShardCoverage,
            "",
            "cores_per_chip is zero",
        ));
        return diags;
    }
    let mut seen = vec![0u32; global.placements.len()];
    for (chip, (local, idxs)) in shards.iter().enumerate() {
        let span = format!("shard {chip}");
        if local.placements.len() != idxs.len() {
            diags.push(Diagnostic::new(
                DiagCode::E007ShardCoverage,
                span,
                format!("{} placements but {} global indices",
                        local.placements.len(), idxs.len()),
            ));
            continue;
        }
        let mut last_gi: Option<usize> = None;
        for (q, &gi) in local.placements.iter().zip(idxs) {
            if gi >= global.placements.len() {
                diags.push(Diagnostic::new(
                    DiagCode::E007ShardCoverage,
                    span.clone(),
                    format!("global index {gi} out of range ({} placements)",
                            global.placements.len()),
                ));
                continue;
            }
            seen[gi] += 1;
            if let Some(prev) = last_gi {
                if gi <= prev {
                    diags.push(Diagnostic::new(
                        DiagCode::E007ShardCoverage,
                        span.clone(),
                        format!("global order not preserved ({gi} after \
                                 {prev})"),
                    ));
                }
            }
            last_gi = Some(gi);
            let g = &global.placements[gi];
            if g.core / cores_per_chip != chip
                || g.core % cores_per_chip != q.core
            {
                diags.push(Diagnostic::new(
                    DiagCode::E007ShardCoverage,
                    format!("{}[{gi}]", g.segment.layer),
                    format!(
                        "global core {} should rebase to chip {} local \
                         core {}, shard {chip} hosts it at local core {}",
                        g.core,
                        g.core / cores_per_chip,
                        g.core % cores_per_chip,
                        q.core
                    ),
                ));
            }
            if q.segment != g.segment
                || q.core_row_off != g.core_row_off
                || q.core_col_off != g.core_col_off
                || q.replica != g.replica
            {
                diags.push(Diagnostic::new(
                    DiagCode::E007ShardCoverage,
                    format!("{}[{gi}]", g.segment.layer),
                    "shard mutated the placement (segment, window offsets \
                     and replica must be preserved verbatim)",
                ));
            }
        }
    }
    for (gi, &n) in seen.iter().enumerate() {
        if n != 1 {
            let layer = &global.placements[gi].segment.layer;
            diags.push(Diagnostic::new(
                DiagCode::E007ShardCoverage,
                format!("{layer}[{gi}]"),
                if n == 0 {
                    "global placement hosted by no shard".to_string()
                } else {
                    format!("global placement hosted by {n} shards")
                },
            ));
        }
    }
    diags
}

/// E014: a routing decision must reference an attached, healthy replica
/// group.  The fleet router gates every dispatch through this check:
/// `detached` marks a group the router took out of rotation after a
/// fault (and that no online repair re-attached), and `health` is the
/// fold of the group's member chips' fault state.  Stuck-at columns
/// alone leave the group routable (degraded accuracy, still serving).
pub fn verify_route(
    model: &str,
    group: usize,
    detached: bool,
    health: &TargetHealth,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let span = format!("{model}/g{group}");
    if detached {
        diags.push(Diagnostic::new(
            DiagCode::E014GroupDetached,
            span.clone(),
            "routing state references a detached replica group",
        ));
    }
    if health.failed {
        diags.push(Diagnostic::new(
            DiagCode::E014GroupDetached,
            span.clone(),
            "replica group has a failed (offline) chip",
        ));
    }
    if !health.failed_cores.is_empty() {
        diags.push(Diagnostic::new(
            DiagCode::E014GroupDetached,
            span,
            format!("replica group has {} dead core(s)",
                    health.failed_cores.len()),
        ));
    }
    diags
}

/// E015: a NEW tenant's placements must not share physical cells with
/// the placements already programmed on a chip.  This is the
/// co-residency twin of the E001 check in [`verify_local`]: E001 guards
/// one plan against itself, E015 guards two independently planned
/// models against each other.  `NeuRramChip::program_plan_co_resident`
/// gates on it before any cell of the new tenant programs.
pub fn verify_co_residency(
    existing: &[SegmentPlacement],
    incoming: &[SegmentPlacement],
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (i, a) in existing.iter().enumerate() {
        for (j, b) in incoming.iter().enumerate() {
            if a.core != b.core || degenerate(a) || degenerate(b) {
                continue;
            }
            let rows_dj = a.phys_rows().end <= b.phys_rows().start
                || b.phys_rows().end <= a.phys_rows().start;
            let cols_dj = a.phys_cols().end <= b.phys_cols().start
                || b.phys_cols().end <= a.phys_cols().start;
            if !rows_dj && !cols_dj {
                diags.push(Diagnostic::new(
                    DiagCode::E015CrossTenantOverlap,
                    format!("{}[{i}] vs {}[{j}]", a.segment.layer,
                            b.segment.layer),
                    format!(
                        "tenant windows overlap on core {}: pair rows \
                         {:?}/{:?}, cols {:?}/{:?}",
                        a.core,
                        a.phys_rows(),
                        b.phys_rows(),
                        a.phys_cols(),
                        b.phys_cols()
                    ),
                ));
            }
        }
    }
    diags
}

/// E016: a `ModelHandle` must still resolve to the model it was issued
/// for.  `models` is the fleet's model-name list in placement order; a
/// handle dangles when its index is out of range or the slot holds a
/// different model (e.g. a handle kept across a fleet rebuild).
pub fn verify_handle(
    id: usize,
    name: &str,
    models: &[&str],
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    match models.get(id) {
        None => diags.push(Diagnostic::new(
            DiagCode::E016DanglingHandle,
            name,
            format!("handle #{id} for model {name} exceeds the fleet's \
                     {} model(s)", models.len()),
        )),
        Some(&have) if have != name => diags.push(Diagnostic::new(
            DiagCode::E016DanglingHandle,
            name,
            format!("handle #{id} was issued for model {name} but the \
                     slot now holds {have}"),
        )),
        Some(_) => {}
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mapping::{
        plan, split_matrix, MappingStrategy, Segment,
    };
    use crate::models::builtin;
    use crate::NUM_CORES;

    fn matrix(name: &str, rows: usize, cols: usize) -> ConductanceMatrix {
        let w = vec![0.1f32; rows * cols];
        ConductanceMatrix::compile(name, &w, None, rows, cols, 7, 40.0, 1.0,
                                   None)
    }

    fn place(layer: &str, rows: usize, cols: usize, core: usize)
             -> SegmentPlacement {
        SegmentPlacement {
            segment: Segment {
                layer: layer.into(),
                row_lo: 0,
                row_hi: rows,
                col_lo: 0,
                col_hi: cols,
            },
            core,
            core_row_off: 0,
            core_col_off: 0,
            replica: 0,
        }
    }

    fn plan_of(placements: Vec<SegmentPlacement>) -> MappingPlan {
        let cores: BTreeSet<usize> =
            placements.iter().map(|p| p.core).collect();
        MappingPlan {
            placements,
            cores_used: cores.len(),
            replicas: Vec::new(),
        }
    }

    fn codes(diags: &[Diagnostic]) -> Vec<DiagCode> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_plan_verifies_clean() {
        let ms = [matrix("a", 64, 64), matrix("b", 300, 100)];
        let p = plan(&ms, &[1.0, 1.0], MappingStrategy::Simple, NUM_CORES)
            .unwrap();
        let diags = verify_model(&p, &ms, NUM_CORES);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn e001_region_overlap() {
        let ms = [matrix("a", 64, 64), matrix("b", 32, 32)];
        let mut pl = vec![place("a", 64, 64, 0), place("b", 32, 32, 0)];
        pl[1].core_row_off = 32; // rows [32,64) x cols [0,32) overlap "a"
        let diags = verify_local(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E001RegionOverlap],
                   "{diags:?}");
    }

    #[test]
    fn e002_region_bounds() {
        let ms = [matrix("a", 64, 64)];
        let mut pl = vec![place("a", 64, 64, 0)];
        pl[0].core_row_off = 100; // 100 + 64 > 128 pair rows
        let diags = verify_local(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E002RegionBounds],
                   "{diags:?}");
        // degenerate (inverted) windows are also E002, without underflow
        let mut pl = vec![place("a", 64, 64, 0)];
        pl[0].segment.row_hi = 0;
        let diags = verify_local(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E002RegionBounds],
                   "{diags:?}");
    }

    #[test]
    fn e003_core_range() {
        let ms = [matrix("a", 64, 64)];
        let pl = vec![place("a", 64, 64, 4)];
        let diags = verify_local(&plan_of(pl), &ms, 4);
        assert_eq!(codes(&diags), vec![DiagCode::E003CoreRange], "{diags:?}");
    }

    #[test]
    fn e004_missing_matrix() {
        let ms = [matrix("a", 64, 64)];
        let pl = vec![place("a", 64, 64, 0), place("ghost", 32, 32, 1)];
        let diags = verify_local(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E004MissingMatrix],
                   "{diags:?}");
    }

    #[test]
    fn e005_segment_coverage() {
        // half-covered matrix: rows [0,32) placed, [32,64) missing
        let ms = [matrix("a", 64, 64)];
        let mut pl = vec![place("a", 64, 64, 0)];
        pl[0].segment.row_hi = 32;
        let diags = verify_model(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E005SegmentCoverage],
                   "{diags:?}");
        // a segment exceeding the compiled matrix is also E005 (local)
        let pl = vec![place("a", 64, 100, 0)];
        let diags = verify_local(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E005SegmentCoverage],
                   "{diags:?}");
    }

    #[test]
    fn e006_replica_bookkeeping() {
        let ms = [matrix("a", 64, 64)];
        // declared 2 replicas, only replica 0 placed
        let mut p = plan_of(vec![place("a", 64, 64, 0)]);
        p.replicas = vec![("a".into(), 2)];
        let diags = verify_model(&p, &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E006ReplicaBookkeeping],
                   "{diags:?}");
        // bookkeeping for a layer that has no compiled matrix
        let mut p = plan_of(vec![place("a", 64, 64, 0)]);
        p.replicas = vec![("a".into(), 1), ("ghost".into(), 2)];
        let diags = verify_model(&p, &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E006ReplicaBookkeeping],
                   "{diags:?}");
        // non-contiguous replica indices
        let mut pl = vec![place("a", 64, 64, 0), place("a", 64, 64, 1)];
        pl[1].replica = 2; // should be 1
        let mut p = plan_of(pl);
        p.replicas = vec![("a".into(), 2)];
        let diags = verify_model(&p, &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::E006ReplicaBookkeeping],
                   "{diags:?}");
    }

    #[test]
    fn e007_shard_coverage() {
        let g = plan_of(vec![place("a", 64, 64, 0), place("b", 64, 64, 1),
                             place("c", 64, 64, 2)]);
        let shard = |cores: &[usize], idxs: &[usize]| {
            let pl: Vec<SegmentPlacement> = idxs
                .iter()
                .zip(cores)
                .map(|(&gi, &core)| {
                    let mut q = g.placements[gi].clone();
                    q.core = core;
                    q
                })
                .collect();
            (plan_of(pl), idxs.to_vec())
        };
        // correct 2-chip sharding at cores_per_chip = 2 verifies clean
        let ok = vec![shard(&[0, 1], &[0, 1]), shard(&[0], &[2])];
        assert!(verify_shards(&g, &ok, 2).is_empty());
        // dropped placement
        let bad = vec![shard(&[0, 1], &[0, 1])];
        let diags = verify_shards(&g, &bad, 2);
        assert_eq!(codes(&diags), vec![DiagCode::E007ShardCoverage],
                   "{diags:?}");
        // duplicated placement
        let bad = vec![shard(&[0, 1], &[0, 1]),
                       shard(&[0, 1], &[1, 2])];
        let diags = verify_shards(&g, &bad, 2);
        assert!(codes(&diags).contains(&DiagCode::E007ShardCoverage),
                "{diags:?}");
        // mis-rebased local core
        let bad = vec![shard(&[0, 0], &[0, 1]), shard(&[0], &[2])];
        let diags = verify_shards(&g, &bad, 2);
        assert!(diags.iter().any(|d| d.code == DiagCode::E007ShardCoverage
                                  && d.message.contains("local core")),
                "{diags:?}");
    }

    #[test]
    fn e008_duplicate_layer() {
        let ms = [matrix("a", 64, 64), matrix("a", 64, 64)];
        let pl = vec![place("a", 64, 64, 0)];
        let diags = verify_model(&plan_of(pl), &ms, NUM_CORES);
        assert!(codes(&diags).contains(&DiagCode::E008DuplicateLayer),
                "{diags:?}");
        // and in the graph
        let mut g = builtin::mnist_cnn7(8);
        let dup = g.layers[0].clone();
        g.layers.push(dup);
        let diags = verify_graph(&g);
        assert!(codes(&diags).contains(&DiagCode::E008DuplicateLayer),
                "{diags:?}");
    }

    #[test]
    fn e009_stochastic_split() {
        let mut g = builtin::rbm_image();
        // widen the hidden layer past one core's columns
        g.layers[0].out_features = CORE_COLS + 1;
        let diags = verify_graph(&g);
        assert_eq!(codes(&diags), vec![DiagCode::E009StochasticSplit],
                   "{diags:?}");
        // the shipped RBM (120 hidden) is fine
        assert!(verify_graph(&builtin::rbm_image()).is_empty());
    }

    #[test]
    fn e010_adc_precision() {
        let mut g = builtin::mnist_cnn7(8);
        g.layers[0].input_bits = 9;
        g.layers[1].output_bits = 0;
        let diags = verify_graph(&g);
        assert_eq!(codes(&diags), vec![DiagCode::E010AdcPrecision,
                                       DiagCode::E010AdcPrecision],
                   "{diags:?}");
        // LSTM gate pair quantized differently
        let mut g = builtin::speech_lstm(32, 1);
        g.layers[1].input_bits = 6; // cell0.wh diverges from cell0.wx
        let diags = verify_graph(&g);
        assert_eq!(codes(&diags), vec![DiagCode::E010AdcPrecision],
                   "{diags:?}");
    }

    #[test]
    fn e011_residual_shape() {
        // open without close
        let mut g = builtin::cifar_resnet(8, 1);
        for l in g.layers.iter_mut() {
            l.res_close = false;
        }
        let diags = verify_graph(&g);
        assert!(codes(&diags).contains(&DiagCode::E011ResidualShape),
                "{diags:?}");
        // close without open
        let mut g = builtin::cifar_resnet(8, 1);
        for l in g.layers.iter_mut() {
            l.res_open = false;
        }
        let diags = verify_graph(&g);
        assert!(codes(&diags).contains(&DiagCode::E011ResidualShape),
                "{diags:?}");
        // channel-shrinking close
        let mut g = builtin::cifar_resnet(8, 1);
        for l in g.layers.iter_mut() {
            if l.res_close {
                l.out_channels = 1;
            }
        }
        let diags = verify_graph(&g);
        assert!(diags.iter().any(|d| d.code == DiagCode::E011ResidualShape
                                  && d.message.contains("channels")),
                "{diags:?}");
        // residual flags on a dense layer
        let mut g = builtin::mnist_cnn7(8);
        g.layers.last_mut().unwrap().res_open = true;
        let diags = verify_graph(&g);
        assert!(codes(&diags).contains(&DiagCode::E011ResidualShape),
                "{diags:?}");
        // the shipped ResNet is fine
        assert!(verify_graph(&builtin::cifar_resnet(16, 3)).is_empty());
    }

    #[test]
    fn e012_chip_budget() {
        let ms: Vec<ConductanceMatrix> =
            (0..4).map(|i| matrix(&format!("m{i}"), 128, 256)).collect();
        let err = plan(&ms, &[1.0; 4], MappingStrategy::Packed, 2)
            .unwrap_err();
        assert!(err.has(DiagCode::E012ChipBudget), "{err}");
        let err = plan(&ms, &[1.0; 4], MappingStrategy::Simple, 2)
            .unwrap_err();
        assert!(err.has(DiagCode::E012ChipBudget), "{err}");
    }

    #[test]
    fn e013_input_arity() {
        let ms = [matrix("a", 64, 64)];
        let err = plan(&ms, &[1.0, 2.0], MappingStrategy::Simple, NUM_CORES)
            .unwrap_err();
        assert_eq!(err.codes(), vec![DiagCode::E013InputArity], "{err}");
    }

    #[test]
    fn w101_replica_shared_core() {
        let ms = [matrix("a", 64, 64)];
        let mut pl = vec![place("a", 64, 64, 0), place("a", 64, 64, 0)];
        pl[1].replica = 1;
        pl[1].core_col_off = 64; // no cell overlap, same core
        let mut p = plan_of(pl);
        p.replicas = vec![("a".into(), 2)];
        let diags = verify_model(&p, &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::W101ReplicaSharedCore],
                   "{diags:?}");
        // warnings alone pass the gate
        assert!(fail_on_errors(diags).is_ok());
    }

    #[test]
    fn w102_unplaced_matrix() {
        let ms = [matrix("a", 64, 64), matrix("aux", 32, 32)];
        let pl = vec![place("a", 64, 64, 0)];
        let diags = verify_local(&plan_of(pl), &ms, NUM_CORES);
        assert_eq!(codes(&diags), vec![DiagCode::W102UnplacedMatrix],
                   "{diags:?}");
    }

    #[test]
    fn split_matrix_plans_verify_clean() {
        // split segments placed one per core reproduce plan() shapes
        let ms = [matrix("tall", 300, 400)];
        let segs = split_matrix("tall", 300, 400);
        let pl: Vec<SegmentPlacement> = segs
            .into_iter()
            .enumerate()
            .map(|(core, segment)| SegmentPlacement {
                segment,
                core,
                core_row_off: 0,
                core_col_off: 0,
                replica: 0,
            })
            .collect();
        let p = plan_of(pl);
        assert!(verify_model(&p, &ms, NUM_CORES).is_empty());
    }

    #[test]
    fn builtin_graphs_verify_clean() {
        for g in [
            builtin::mnist_cnn7(8),
            builtin::cifar_resnet(16, 3),
            builtin::speech_lstm(64, 2),
            builtin::rbm_image(),
        ] {
            let diags = verify_graph(&g);
            assert!(diags.is_empty(), "{}: {diags:?}", g.name);
        }
    }

    #[test]
    fn e014_rejects_detached_or_unhealthy_routes() {
        // healthy + attached: routable
        let ok = TargetHealth::default();
        assert!(verify_route("edge", 0, false, &ok).is_empty());
        // stuck-at columns degrade accuracy but do NOT detach
        let stuck = TargetHealth { stuck_columns: 2, ..Default::default() };
        assert!(verify_route("edge", 0, false, &stuck).is_empty());
        // detached by the router
        let d = verify_route("edge", 1, true, &ok);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].code, DiagCode::E014GroupDetached);
        assert_eq!(d[0].span, "edge/g1");
        assert!(fail_on_errors(d).is_err());
        // failed chip and dead cores each flag
        let down = TargetHealth { failed: true, ..Default::default() };
        assert!(verify_route("edge", 0, false, &down)
            .iter()
            .all(|x| x.code == DiagCode::E014GroupDetached));
        let dead = TargetHealth {
            failed_cores: vec![3],
            ..Default::default()
        };
        let d = verify_route("edge", 2, false, &dead);
        assert_eq!(d.len(), 1);
        assert!(d[0].message.contains("dead core"));
    }

    #[test]
    fn e015_flags_only_cross_tenant_cell_overlap() {
        // tenant A holds a 64x64 window at (0, 0) on core 0
        let a = vec![place("a::fc", 64, 64, 0)];
        // disjoint columns on the same core: legal co-residency
        let mut ok = vec![place("b::fc", 64, 64, 0)];
        ok[0].core_col_off = 64;
        assert!(verify_co_residency(&a, &ok).is_empty());
        // a different core never overlaps
        let other = vec![place("b::fc", 64, 64, 1)];
        assert!(verify_co_residency(&a, &other).is_empty());
        // overlapping rows AND columns: E015
        let mut bad = vec![place("b::fc", 64, 64, 0)];
        bad[0].core_row_off = 32;
        let d = verify_co_residency(&a, &bad);
        assert_eq!(codes(&d), vec![DiagCode::E015CrossTenantOverlap],
                   "{d:?}");
        assert!(d[0].span.contains("a::fc"), "{:?}", d[0].span);
        assert!(d[0].span.contains("b::fc"), "{:?}", d[0].span);
        assert!(fail_on_errors(d).is_err());
    }

    #[test]
    fn e016_flags_dangling_handles() {
        let models = ["edge", "cifar"];
        // a live handle resolves silently
        assert!(verify_handle(1, "cifar", &models).is_empty());
        // index past the model list
        let d = verify_handle(2, "ghost", &models);
        assert_eq!(codes(&d), vec![DiagCode::E016DanglingHandle]);
        assert!(d[0].message.contains("exceeds"), "{}", d[0].message);
        // slot reused by a different model
        let d = verify_handle(0, "cifar", &models);
        assert_eq!(codes(&d), vec![DiagCode::E016DanglingHandle]);
        assert!(d[0].message.contains("now holds edge"), "{}",
                d[0].message);
        assert!(fail_on_errors(d).is_err());
    }
}
