//! Model-driven chip calibration (paper Fig. 3b, Extended Data Fig. 5).

pub mod calibrate;

pub use calibrate::{calibrate_layer_shift, measure_adc_offsets, CalibReport};
