//! Model-driven chip calibration.
//!
//! Two knobs, per the paper:
//!  1. operating-condition search -- per layer, run *training-set* data
//!     through the programmed layer and pick the requantization shift so
//!     the output distribution fills the next layer's input range
//!     (ED Fig. 5 shows why the calibration data must match the test-time
//!     distribution: uniform-random probes give a very different output
//!     distribution);
//!  2. ADC offset measurement -- drive each neuron directly in
//!     neuron-testing mode and record the code at zero input, to be
//!     subtracted during inference (non-ideality (vii)).

use crate::coordinator::{DispatchTarget, NeuRramChip};
use crate::core_sim::NeuronConfig;
use crate::models::quant::calibrate_shift;
use crate::util::stats::percentile;

#[derive(Clone, Debug, Default)]
pub struct CalibReport {
    pub layer: String,
    pub shift: f64,
    pub p99: f64,
    pub samples: usize,
}

/// Calibrate one layer's requantization shift from measured outputs on a
/// set of probe inputs (which should come from training data).
pub fn calibrate_layer_shift<T: DispatchTarget>(
    chip: &mut T,
    layer: &str,
    probes: &[Vec<i32>],
    cfg: &NeuronConfig,
    next_bits: u32,
) -> CalibReport {
    let mut vals = Vec::new();
    for x in probes {
        let y = chip.mvm_layer(layer, x, cfg, 0);
        for v in y {
            vals.push(v.max(0.0));
        }
    }
    let p99 = percentile(&vals, 99.0);
    let shift = calibrate_shift(p99, next_bits);
    // one chip-lane Calibrate marker per calibrated layer (zero width:
    // the probe MVMs already recorded their own spans)
    if let Some(rec) = chip.telemetry() {
        if rec.is_enabled() {
            let lid = rec.intern(layer);
            rec.record_tiled(
                0.0,
                crate::telemetry::EventKind::Calibrate { layer: lid, shift },
            );
        }
    }
    CalibReport {
        layer: layer.to_string(),
        shift,
        p99,
        samples: vals.len(),
    }
}

/// Measure per-neuron ADC offsets in neuron-testing mode: the digital
/// code at zero analog input, expressed in volts to subtract.
pub fn measure_adc_offsets(chip: &NeuRramChip, core: usize,
                           cfg: &NeuronConfig) -> Vec<f64> {
    let c = &chip.cores[core];
    // In the simulator offsets live in NeuronConfig::offset_v; measuring
    // them through the test mode returns the quantized view of that
    // offset, mirroring the on-chip procedure.
    let n = crate::CORE_COLS;
    (0..n)
        .map(|_| {
            let code = c.neuron_test(0.0, cfg);
            code as f64 * cfg.v_decr()
        })
        .collect()
}

/// Progressive whole-CNN shift calibration on probe images: runs the
/// network layer by layer with the shifts found so far and applies the
/// percentile rule at each step (the rust mirror of
/// `noise_train.calibrate_shifts`).
///
/// The probe forward rides the REAL batched executor in ONE walk of
/// the graph (`executor::cnn::calibrate_shifts_progressive` -- each
/// layer is calibrated from the state advanced with the shifts chosen
/// so far), so residual skip connections and every other executor
/// detail shape the calibration features exactly as they shape
/// inference, at O(L) layer executions instead of O(L^2).
pub fn calibrate_cnn_shifts<T: DispatchTarget>(
    chip: &mut T,
    graph: &crate::models::ModelGraph,
    probe_imgs: &[Vec<f32>],
) -> Vec<f64> {
    use crate::models::executor::cnn::{calibrate_shifts_progressive,
                                       quantize_inputs};
    let imgs_q = quantize_inputs(graph, probe_imgs);
    let n_probe = probe_imgs.len().max(1);
    calibrate_shifts_progressive(chip, graph, &imgs_q, |chip, li, inputs| {
        let layer = &graph.layers[li];
        let next_bits = graph.layers[li + 1].input_bits;
        // sample patches dispersed across the feature maps -- corner
        // patches are mostly padding and would skew the percentile
        let stride = (inputs.len() / (24 * n_probe)).max(1);
        let probes: Vec<Vec<i32>> =
            inputs.into_iter().step_by(stride).collect();
        let cfg = NeuronConfig {
            input_bits: layer.input_bits,
            output_bits: layer.output_bits,
            ..Default::default()
        };
        calibrate_layer_shift(chip, &layer.name, &probes, &cfg,
                              next_bits - 1)
            .shift
    })
}

/// Run conv layers [0, upto) and return the im2col patches entering layer
/// `upto` (legacy per-image probe collection; residual skips are NOT
/// modelled here -- `executor::cnn::calibrate_shifts_progressive` is
/// the executor-faithful path the CNN calibration uses).
pub fn forward_collect_patches<T: DispatchTarget>(
    chip: &mut T,
    graph: &crate::models::ModelGraph,
    img_q: &[i32],
    shifts: &[f64],
    upto: usize,
) -> Vec<Vec<i32>> {
    use crate::models::executor::{extract_patch, FeatureMap};
    use crate::models::{quant, LayerKind};
    let mut fm = FeatureMap {
        h: graph.input_hw,
        w: graph.input_hw,
        c: graph.input_ch,
        data: img_q.to_vec(),
    };
    for li in 0..upto {
        let layer = &graph.layers[li];
        if layer.kind != LayerKind::Conv {
            break;
        }
        let cfg = NeuronConfig {
            input_bits: layer.input_bits,
            output_bits: layer.output_bits,
            ..Default::default()
        };
        let next_bits = graph.layers[li + 1].input_bits;
        let oc = layer.out_features;
        let mut vals = vec![0.0f64; fm.h * fm.w * oc];
        for y in 0..fm.h {
            for x in 0..fm.w {
                let patch = extract_patch(&fm, y, x, layer.kh, layer.kw);
                let out = chip.mvm_layer(&layer.name, &patch, &cfg, 0);
                for (ch, v) in out.iter().enumerate() {
                    vals[(y * fm.w + x) * oc + ch] = v.max(0.0);
                }
            }
        }
        let k = layer.pool.max(1);
        let (nh, nw) = (fm.h / k, fm.w / k);
        let mut next = FeatureMap::new(nh, nw, oc);
        for y in 0..nh {
            for x in 0..nw {
                for ch in 0..oc {
                    let mut m = f64::MIN;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(
                                vals[((y * k + dy) * fm.w + x * k + dx) * oc
                                    + ch],
                            );
                        }
                    }
                    next.data[(y * nw + x) * oc + ch] =
                        quant::requantize_unsigned(m, shifts[li],
                                                   next_bits - 1);
                }
            }
        }
        fm = next;
    }
    let layer = &graph.layers[upto];
    if layer.kind == crate::models::LayerKind::Conv {
        use crate::models::executor::extract_patch;
        let mut patches = Vec::new();
        for y in 0..fm.h {
            for x in 0..fm.w {
                patches.push(extract_patch(&fm, y, x, layer.kh, layer.kw));
            }
        }
        patches
    } else {
        vec![fm.data]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mapping::MappingStrategy;
    use crate::models::ConductanceMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn shift_fills_next_range() {
        let mut rng = Rng::new(21);
        let w: Vec<f32> = (0..64 * 16).map(|_| rng.normal() as f32).collect();
        let m = ConductanceMatrix::compile("l", &w, None, 64, 16, 7, 40.0,
                                           1.0, None);
        let mut chip = NeuRramChip::with_cores(2, 22);
        chip.program_model(vec![m], &[1.0], MappingStrategy::Simple, false)
            .unwrap();
        let probes: Vec<Vec<i32>> = (0..16)
            .map(|_| (0..64).map(|_| rng.below(8) as i32).collect())
            .collect();
        let cfg = NeuronConfig::default();
        let rep = calibrate_layer_shift(&mut chip, "l", &probes, &cfg, 3);
        assert!(rep.p99 > 0.0);
        // requantized p99 must land inside [0, 7]
        let q = rep.p99 / 2f64.powf(rep.shift);
        assert!(q <= 7.0 + 1e-9, "q = {q}");
    }

    #[test]
    fn offsets_zero_for_ideal_neurons() {
        let chip = NeuRramChip::with_cores(1, 23);
        let cfg = NeuronConfig::default();
        let offs = measure_adc_offsets(&chip, 0, &cfg);
        assert!(offs.iter().all(|&o| o == 0.0));
        let cfg_off = NeuronConfig { offset_v: 0.02, ..Default::default() };
        let offs = measure_adc_offsets(&chip, 0, &cfg_off);
        assert!(offs.iter().all(|&o| o > 0.0));
    }
}
