//! Minimal JSON parser + serializer (serde is not available offline).
//! Supports the full JSON grammar needed by the artifact manifest and the
//! config system: objects, arrays, strings (with escapes), numbers,
//! booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape vector helper: `[128, 256]` -> `vec![128, 256]`.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ---- serialization ----
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl std::ops::Index<&str> for Json {
    type Output = Json;
    /// Panics-free indexing: missing keys yield `Json::Null`.
    fn index(&self, k: &str) -> &Json {
        const NULL: Json = Json::Null;
        self.get(k).unwrap_or(&NULL)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let j = Json::parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e2}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("b").unwrap().idx(0).unwrap().as_bool(), Some(true));
        assert_eq!(j.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\n"));
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-250.0));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr": [1, 2, [3, {"k": "v"}]], "s": "q\"uote", "n": null}"#;
        let j = Json::parse(src).unwrap();
        let enc = j.to_string_pretty();
        let j2 = Json::parse(&enc).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn shapes() {
        let j = Json::parse("[128, 256]").unwrap();
        assert_eq!(j.as_shape(), Some(vec![128, 256]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
