//! Machine-readable bench output: a tiny writer for the `BENCH_*.json`
//! perf-trajectory files the benches emit next to their stdout tables.
//!
//! Every record carries the bench name plus flat numeric / string /
//! numeric-array fields, serialized through [`crate::util::json::Json`]
//! (stable key order via `BTreeMap`), so future PRs can diff perf by
//! comparing two files: run the bench before and after a change and
//! compare e.g. `.chip_batch32_speedup_t4` directly.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Builder for one `BENCH_<name>.json` record.
#[derive(Debug, Default)]
pub struct BenchJson {
    root: BTreeMap<String, Json>,
}

impl BenchJson {
    pub fn new(bench: &str) -> Self {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str(bench.to_string()));
        BenchJson { root }
    }

    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        self.root.insert(key.to_string(), Json::Num(v));
        self
    }

    pub fn text(&mut self, key: &str, v: &str) -> &mut Self {
        self.root.insert(key.to_string(), Json::Str(v.to_string()));
        self
    }

    pub fn nums(&mut self, key: &str, vs: &[f64]) -> &mut Self {
        self.root.insert(
            key.to_string(),
            Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect()),
        );
        self
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.root.clone())
    }

    /// Write the record to `path` (conventionally `BENCH_<name>.json` in
    /// the working directory the bench runs from).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        std::fs::write(path, s)?;
        println!("  wrote {path}");
        Ok(())
    }
}

/// Run metadata stamped onto every emitted `BENCH_*.json` record (and,
/// minus the thread count, onto trace exports): enough provenance to
/// line artifacts up across CI runs when ratcheting the perf
/// trajectory.
#[derive(Clone, Debug)]
pub struct RunMeta {
    /// Short git commit, `NEURRAM_GIT_COMMIT` override first (CI sets
    /// it), `git rev-parse` fallback, `"unknown"` when neither works.
    pub commit: String,
    pub threads: usize,
    pub chips: usize,
    pub seed: u64,
}

impl RunMeta {
    pub fn capture(chips: usize, seed: u64) -> Self {
        let commit = std::env::var("NEURRAM_GIT_COMMIT")
            .ok()
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .or_else(|| {
                std::process::Command::new("git")
                    .args(["rev-parse", "--short", "HEAD"])
                    .output()
                    .ok()
                    .filter(|o| o.status.success())
                    .map(|o| {
                        String::from_utf8_lossy(&o.stdout).trim().to_string()
                    })
                    .filter(|s| !s.is_empty())
            })
            .unwrap_or_else(|| "unknown".to_string());
        RunMeta {
            commit,
            threads: crate::util::threads::resolve(),
            chips,
            seed,
        }
    }

    /// Stamp the provenance fields onto a bench record.
    pub fn stamp(&self, b: &mut BenchJson) {
        b.text("run_commit", &self.commit)
            .num("run_threads", self.threads as f64)
            .num("run_chips", self.chips as f64)
            .num("run_seed", self.seed as f64);
    }

    /// Metadata pairs for a Chrome trace export.  Deliberately OMITS
    /// the thread count: trace bytes are pinned identical across
    /// `NEURRAM_THREADS` settings, and a thread stamp would break that
    /// by construction.
    pub fn trace_meta(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("commit", Json::Str(self.commit.clone())),
            ("chips", Json::Num(self.chips as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_meta_stamps_provenance_keys() {
        let meta = RunMeta {
            commit: "abc1234".to_string(),
            threads: 4,
            chips: 2,
            seed: 21,
        };
        let mut b = BenchJson::new("x");
        meta.stamp(&mut b);
        let j = b.to_json();
        assert_eq!(j["run_commit"].as_str(), Some("abc1234"));
        assert_eq!(j["run_threads"].as_f64(), Some(4.0));
        assert_eq!(j["run_chips"].as_f64(), Some(2.0));
        assert_eq!(j["run_seed"].as_f64(), Some(21.0));
        // trace metadata must not leak the thread count (byte-identity
        // across NEURRAM_THREADS)
        assert!(meta.trace_meta().iter().all(|(k, _)| *k != "threads"));
    }

    #[test]
    fn record_roundtrips_through_json() {
        let mut b = BenchJson::new("hotpath");
        b.num("speedup", 2.5)
            .text("mode", "full")
            .nums("curve", &[1.0, 1.9, 3.6]);
        let enc = b.to_json().to_string_pretty();
        let back = Json::parse(&enc).unwrap();
        assert_eq!(back["bench"].as_str(), Some("hotpath"));
        assert_eq!(back["speedup"].as_f64(), Some(2.5));
        assert_eq!(back["curve"].idx(2).and_then(|j| j.as_f64()), Some(3.6));
    }
}
