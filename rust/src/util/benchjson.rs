//! Machine-readable bench output: a tiny writer for the `BENCH_*.json`
//! perf-trajectory files the benches emit next to their stdout tables.
//!
//! Every record carries the bench name plus flat numeric / string /
//! numeric-array fields, serialized through [`crate::util::json::Json`]
//! (stable key order via `BTreeMap`), so future PRs can diff perf by
//! comparing two files: run the bench before and after a change and
//! compare e.g. `.chip_batch32_speedup_t4` directly.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Builder for one `BENCH_<name>.json` record.
#[derive(Debug, Default)]
pub struct BenchJson {
    root: BTreeMap<String, Json>,
}

impl BenchJson {
    pub fn new(bench: &str) -> Self {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str(bench.to_string()));
        BenchJson { root }
    }

    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        self.root.insert(key.to_string(), Json::Num(v));
        self
    }

    pub fn text(&mut self, key: &str, v: &str) -> &mut Self {
        self.root.insert(key.to_string(), Json::Str(v.to_string()));
        self
    }

    pub fn nums(&mut self, key: &str, vs: &[f64]) -> &mut Self {
        self.root.insert(
            key.to_string(),
            Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect()),
        );
        self
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.root.clone())
    }

    /// Write the record to `path` (conventionally `BENCH_<name>.json` in
    /// the working directory the bench runs from).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        std::fs::write(path, s)?;
        println!("  wrote {path}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let mut b = BenchJson::new("hotpath");
        b.num("speedup", 2.5)
            .text("mode", "full")
            .nums("curve", &[1.0, 1.9, 3.6]);
        let enc = b.to_json().to_string_pretty();
        let back = Json::parse(&enc).unwrap();
        assert_eq!(back["bench"].as_str(), Some("hotpath"));
        assert_eq!(back["speedup"].as_f64(), Some(2.5));
        assert_eq!(back["curve"].idx(2).and_then(|j| j.as_f64()), Some(3.6));
    }
}
