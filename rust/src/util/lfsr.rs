//! Linear-feedback shift registers mirroring the chip's pseudo-random
//! source (paper Extended Data Fig. 1d): two LFSR chains propagating in
//! opposite directions whose registers are XORed to produce spatially
//! uncorrelated per-neuron random bits for probabilistic sampling.

/// Maximal-length 16-bit Fibonacci LFSR (taps 16,15,13,4 -> period 2^16-1).
#[derive(Clone, Debug)]
pub struct Lfsr16 {
    state: u16,
}

impl Lfsr16 {
    pub fn new(seed: u16) -> Self {
        Lfsr16 { state: if seed == 0 { 0xACE1 } else { seed } }
    }

    #[inline]
    pub fn step(&mut self) -> u16 {
        let s = self.state;
        let bit = (s ^ (s >> 2) ^ (s >> 3) ^ (s >> 5)) & 1;
        self.state = (s >> 1) | (bit << 15);
        self.state
    }

    pub fn state(&self) -> u16 {
        self.state
    }
}

/// The chip's sampling-noise block: two counter-propagating chains of
/// per-neuron registers; neuron j's random word is `fwd[j] ^ bwd[j]`.
#[derive(Clone, Debug)]
pub struct LfsrChains {
    fwd: Vec<u16>,
    bwd: Vec<u16>,
    gen_f: Lfsr16,
    gen_b: Lfsr16,
}

impl LfsrChains {
    pub fn new(n: usize, seed: u16) -> Self {
        let mut gen_f = Lfsr16::new(seed);
        let mut gen_b = Lfsr16::new(seed.wrapping_mul(31).wrapping_add(17));
        let fwd: Vec<u16> = (0..n).map(|_| gen_f.step()).collect();
        let bwd: Vec<u16> = (0..n).map(|_| gen_b.step()).collect();
        LfsrChains { fwd, bwd, gen_f, gen_b }
    }

    /// Advance both chains one cycle: forward chain shifts toward higher
    /// indices, backward chain toward lower (counter-propagating).
    pub fn step(&mut self) {
        let n = self.fwd.len();
        for i in (1..n).rev() {
            self.fwd[i] = self.fwd[i - 1];
        }
        self.fwd[0] = self.gen_f.step();
        for i in 0..n - 1 {
            self.bwd[i] = self.bwd[i + 1];
        }
        self.bwd[n - 1] = self.gen_b.step();
    }

    /// Per-neuron random word.
    #[inline]
    pub fn word(&self, j: usize) -> u16 {
        self.fwd[j] ^ self.bwd[j]
    }

    /// Per-neuron noise voltage, uniform in [-amp, amp] (injected into the
    /// neuron integrator during stochastic sampling).
    #[inline]
    pub fn noise(&self, j: usize, amp: f32) -> f32 {
        let w = self.word(j) as f32 / 65535.0; // [0,1]
        amp * (2.0 * w - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfsr_full_period() {
        let mut l = Lfsr16::new(1);
        let start = l.state();
        let mut n = 0u32;
        loop {
            l.step();
            n += 1;
            if l.state() == start {
                break;
            }
            assert!(n < 70_000);
        }
        assert_eq!(n, 65_535);
    }

    #[test]
    fn lfsr_never_zero() {
        let mut l = Lfsr16::new(0); // auto-reseeded
        for _ in 0..10_000 {
            assert_ne!(l.step(), 0);
        }
    }

    #[test]
    fn chains_spatially_uncorrelated() {
        let mut c = LfsrChains::new(256, 0xBEEF);
        // correlation between adjacent neuron words over time
        let mut same_bits = 0u32;
        let mut total = 0u32;
        for _ in 0..200 {
            c.step();
            for j in 0..255 {
                same_bits += (c.word(j) ^ c.word(j + 1)).count_zeros();
                total += 16;
            }
        }
        let frac = same_bits as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.02, "adjacent-bit agreement {frac}");
    }

    #[test]
    fn noise_bounded_and_centered() {
        let mut c = LfsrChains::new(64, 7);
        let mut sum = 0.0f64;
        let mut n = 0;
        for _ in 0..500 {
            c.step();
            for j in 0..64 {
                let v = c.noise(j, 0.1);
                assert!(v.abs() <= 0.1 + 1e-6);
                sum += v as f64;
                n += 1;
            }
        }
        assert!((sum / n as f64).abs() < 2e-3);
    }
}
