//! Chip + run configuration: a JSON-backed config system so deployments
//! can adjust the simulator without recompiling
//! (`neurram <cmd> --config chip.json`).
//!
//! Any field may be omitted; defaults mirror the paper's 130 nm chip.

use crate::core_sim::CrossbarNonIdealities;
use crate::device::{DeviceParams, WriteVerifyConfig};
use crate::energy::EnergyParams;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

#[derive(Clone, Debug)]
pub struct ChipConfig {
    pub num_cores: usize,
    pub seed: u64,
    pub device: DeviceParams,
    pub write_verify: WriteVerifyConfig,
    pub nonideal: CrossbarNonIdealities,
    pub energy: EnergyParams,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            num_cores: crate::NUM_CORES,
            seed: 0,
            device: DeviceParams::default(),
            write_verify: WriteVerifyConfig::default(),
            nonideal: CrossbarNonIdealities::default(),
            energy: EnergyParams::default(),
        }
    }
}

fn get_f64(j: &Json, key: &str, out: &mut f64) {
    if let Some(v) = j.get(key).and_then(|v| v.as_f64()) {
        *out = v;
    }
}

fn get_usize(j: &Json, key: &str, out: &mut usize) {
    if let Some(v) = j.get(key).and_then(|v| v.as_usize()) {
        *out = v;
    }
}

impl ChipConfig {
    pub fn from_file(path: &str) -> Result<ChipConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading chip config {path}"))?;
        Self::from_json(&text)
            .with_context(|| format!("parsing chip config {path}"))
    }

    pub fn from_json(text: &str) -> Result<ChipConfig> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut c = ChipConfig::default();
        get_usize(&j, "num_cores", &mut c.num_cores);
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            c.seed = v as u64;
        }
        if let Some(d) = j.get("device") {
            get_f64(d, "g_min_us", &mut c.device.g_min_us);
            get_f64(d, "g_max_us", &mut c.device.g_max_us);
            get_f64(d, "relax_sigma_peak_us", &mut c.device.relax_sigma_peak_us);
            get_f64(d, "read_sigma_us", &mut c.device.read_sigma_us);
            get_f64(d, "pulse_sigma", &mut c.device.pulse_sigma);
            get_f64(d, "retention_tau_s", &mut c.device.retention_tau_s);
            get_f64(d, "endurance_cycles", &mut c.device.endurance_cycles);
        }
        if let Some(w) = j.get("write_verify") {
            get_f64(w, "accept_us", &mut c.write_verify.accept_us);
            get_f64(w, "set_v0", &mut c.write_verify.set_v0);
            get_f64(w, "reset_v0", &mut c.write_verify.reset_v0);
            get_f64(w, "v_step", &mut c.write_verify.v_step);
            if let Some(v) = w.get("max_reversals").and_then(|v| v.as_usize()) {
                c.write_verify.max_reversals = v as u32;
            }
            if let Some(v) = w.get("iterations").and_then(|v| v.as_usize()) {
                c.write_verify.iterations = v as u32;
            }
        }
        if let Some(n) = j.get("nonidealities") {
            get_f64(n, "ir_alpha", &mut c.nonideal.ir_alpha);
            get_f64(n, "coupling_sigma_v", &mut c.nonideal.coupling_sigma_v);
        }
        if let Some(e) = j.get("energy") {
            get_f64(e, "e_wl_toggle_pj", &mut c.energy.e_wl_toggle_pj);
            get_f64(e, "e_input_wire_pj", &mut c.energy.e_input_wire_pj);
            get_f64(e, "t_adc_step_ns", &mut c.energy.t_adc_step_ns);
            get_f64(e, "t_settle_ns", &mut c.energy.t_settle_ns);
        }
        if c.num_cores == 0 || c.num_cores > 1024 {
            return Err(anyhow!("num_cores {} out of range", c.num_cores));
        }
        Ok(c)
    }

    /// Dump the effective configuration as JSON (for reproducibility
    /// records in experiment logs).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut device = BTreeMap::new();
        device.insert("g_min_us".into(), Json::Num(self.device.g_min_us));
        device.insert("g_max_us".into(), Json::Num(self.device.g_max_us));
        device.insert("relax_sigma_peak_us".into(),
                      Json::Num(self.device.relax_sigma_peak_us));
        device.insert("retention_tau_s".into(),
                      Json::Num(self.device.retention_tau_s));
        device.insert("endurance_cycles".into(),
                      Json::Num(self.device.endurance_cycles));
        let mut wv = BTreeMap::new();
        wv.insert("accept_us".into(), Json::Num(self.write_verify.accept_us));
        wv.insert("iterations".into(),
                  Json::Num(self.write_verify.iterations as f64));
        let mut ni = BTreeMap::new();
        ni.insert("ir_alpha".into(), Json::Num(self.nonideal.ir_alpha));
        ni.insert("coupling_sigma_v".into(),
                  Json::Num(self.nonideal.coupling_sigma_v));
        let mut top = BTreeMap::new();
        top.insert("num_cores".into(), Json::Num(self.num_cores as f64));
        top.insert("seed".into(), Json::Num(self.seed as f64));
        top.insert("device".into(), Json::Obj(device));
        top.insert("write_verify".into(), Json::Obj(wv));
        top.insert("nonidealities".into(), Json::Obj(ni));
        Json::Obj(top)
    }

    /// Build a chip from this configuration.
    pub fn build_chip(&self) -> crate::coordinator::NeuRramChip {
        let mut chip =
            crate::coordinator::NeuRramChip::with_cores(self.num_cores,
                                                        self.seed);
        chip.ir_alpha = self.nonideal.ir_alpha;
        for core in &mut chip.cores {
            core.array.params = self.device.clone();
            core.g_max_us = self.device.g_max_us;
        }
        chip
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ChipConfig::default();
        assert_eq!(c.num_cores, 48);
        assert_eq!(c.device.g_max_us, 40.0);
        assert_eq!(c.write_verify.iterations, 3);
    }

    #[test]
    fn partial_override() {
        let c = ChipConfig::from_json(
            r#"{"num_cores": 16,
                "device": {"g_max_us": 30.0},
                "nonidealities": {"ir_alpha": 0.4},
                "write_verify": {"iterations": 5}}"#,
        )
        .unwrap();
        assert_eq!(c.num_cores, 16);
        assert_eq!(c.device.g_max_us, 30.0);
        assert_eq!(c.device.g_min_us, 1.0); // untouched default
        assert_eq!(c.nonideal.ir_alpha, 0.4);
        assert_eq!(c.write_verify.iterations, 5);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ChipConfig::from_json(r#"{"num_cores": 0}"#).is_err());
        assert!(ChipConfig::from_json("not json").is_err());
    }

    #[test]
    fn roundtrip_through_json_dump() {
        let c = ChipConfig::from_json(
            r#"{"num_cores": 8, "nonidealities": {"ir_alpha": 0.25}}"#,
        )
        .unwrap();
        let dumped = c.to_json().to_string_pretty();
        let c2 = ChipConfig::from_json(&dumped).unwrap();
        assert_eq!(c2.num_cores, 8);
        assert_eq!(c2.nonideal.ir_alpha, 0.25);
    }

    #[test]
    fn builds_configured_chip() {
        let c = ChipConfig::from_json(
            r#"{"num_cores": 4, "seed": 9, "device": {"g_max_us": 30.0}}"#,
        )
        .unwrap();
        let chip = c.build_chip();
        assert_eq!(chip.cores.len(), 4);
        assert_eq!(chip.cores[0].g_max_us, 30.0);
    }
}
