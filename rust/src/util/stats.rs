//! Small statistics helpers shared by the device model, calibration and
//! the bench harness.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() as f64 - 1.0))
        .sqrt()
}

/// Percentile (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = p / 100.0 * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Fixed-width histogram over [lo, hi); returns bin counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        }
    }
    h
}

/// Render a one-line ASCII sparkline of a histogram (for CLI reports).
pub fn sparkline(counts: &[usize]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = counts.iter().copied().max().unwrap_or(0).max(1);
    counts
        .iter()
        .map(|&c| GLYPHS[(c * 7 + max / 2) / max])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn histogram_bins() {
        let xs = [0.1, 0.2, 0.9];
        assert_eq!(histogram(&xs, 0.0, 1.0, 2), vec![2, 1]);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
