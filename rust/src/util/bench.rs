//! Micro-benchmark harness (criterion is not available offline).
//!
//! Provides warmup + repeated timing with median/mean/min reporting, and a
//! tabular printer used by every `cargo bench` target to emit the paper's
//! tables/figures as text.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} iters={:<6} mean={:>12} median={:>12} min={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` with automatic iteration count targeting ~`budget_ms` total.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // warmup + estimate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((budget_ms as f64 * 1e6 / once) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
    };
    res.report();
    res
}

/// Print a section header for a paper table/figure reproduction.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print an aligned table: header row + data rows.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

/// `black_box` stand-in to defeat the optimizer in bench loops.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
