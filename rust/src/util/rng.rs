//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64, plus Gaussian
//! sampling (Box-Muller with caching).  Used by every stochastic model in
//! the simulator so that runs are reproducible from a single seed.
//!
//! [`stream`] derives counter-addressed generators: the returned `Rng`
//! is a pure function of `(seed, stream_id, counter)`, so independent
//! execution units (the CIM cores) can draw noise concurrently with a
//! sequence that does not depend on thread interleaving or on how many
//! draws any *other* unit made.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Counter-derived stream: an independent generator that is a pure
/// function of `(seed, stream_id, counter)`.  Each of the three words is
/// folded through a SplitMix64 avalanche before seeding the xoshiro
/// state, so neighbouring ids/counters land on unrelated streams.
///
/// The chip uses `(chip seed, core id, per-core item counter)`: a
/// dispatched item's draw sequence depends only on which core ran it and
/// how many items that core had dispatched before -- never on wall-clock
/// scheduling (see `coordinator/chip.rs`).
pub fn stream(seed: u64, stream_id: u64, counter: u64) -> Rng {
    let mut s = seed;
    let a = splitmix64(&mut s);
    s = a ^ stream_id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let b = splitmix64(&mut s);
    s = b ^ counter.wrapping_mul(0xD1B5_4A32_D192_ED03);
    Rng::new(splitmix64(&mut s))
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-core / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            v.swap(i, self.below(i + 1));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn stream_is_pure_function_of_its_coordinates() {
        let mut a = stream(9, 3, 41);
        let mut b = stream(9, 3, 41);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn stream_coordinates_decorrelate() {
        // neighbouring ids and counters must land on unrelated streams
        for (sid, ctr) in [(3u64, 42u64), (4, 41), (2, 41), (3, 40)] {
            let mut base = stream(9, 3, 41);
            let mut other = stream(9, sid, ctr);
            let same = (0..64)
                .filter(|_| base.next_u64() == other.next_u64())
                .count();
            assert!(same < 4, "stream ({sid},{ctr}) collides: {same}/64");
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(4);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
