//! Worker-thread knob for the parallel dispatch engine.
//!
//! `NEURRAM_THREADS` selects how many OS threads the chip fans
//! segment-parallel MVM work out to (`NeuRramChip::threads`):
//!
//! * unset / `0` / unparsable -> `std::thread::available_parallelism()`
//! * `1`                      -> the serial oracle (today's dispatch
//!                               order on the calling thread)
//! * `n > 1`                  -> up to `n` scoped worker threads
//!
//! Outputs are bitwise identical at every setting: per-core RNG streams
//! are counter-derived (see `util::rng::stream`) and partial sums are
//! accumulated in placement order after the fan-out joins, so the knob
//! trades wall-clock only.  The CLI mirrors it as `--threads n`.

/// Environment variable naming the worker-thread count.
pub const THREADS_ENV: &str = "NEURRAM_THREADS";

/// Number of worker threads the hardware offers (fallback 1).
pub fn available() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve the effective thread count from `NEURRAM_THREADS`.
pub fn resolve() -> usize {
    match std::env::var(THREADS_ENV) {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => available(),
        },
        Err(_) => available(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_is_at_least_one() {
        assert!(available() >= 1);
    }

    #[test]
    fn resolve_is_at_least_one() {
        // whatever the ambient env says, the result must be usable
        assert!(resolve() >= 1);
    }
}
