//! Support utilities implemented in-tree (this build environment is
//! offline: no serde/clap/rand/criterion), all substrates in their own
//! right: the LFSR mirrors the chip's probabilistic-sampling hardware.

pub mod bench;
pub mod benchjson;
pub mod config;
pub mod cli;
pub mod json;
pub mod lfsr;
pub mod rng;
pub mod stats;
pub mod threads;
