//! Tiny CLI argument parser (clap is not available offline).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] ...`
//!
//! Typed accessors return `anyhow::Result`: an ABSENT option yields its
//! default, but a PRESENT option that fails to parse is a user error
//! and reports which flag and value were rejected instead of silently
//! falling back to the default (the old behaviour turned typos like
//! `--batch 3O` into surprise defaults).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::fmt::Display;
use std::str::FromStr;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Args {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) =
                    it.next_if(|n| !n.starts_with("--"))
                {
                    a.options.insert(name.to_string(), v);
                } else {
                    a.flags.push(name.to_string());
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok);
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option: `default` when absent, `Err` naming the flag and
    /// offending value when present but unparsable.
    fn parsed<T: FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| {
                anyhow!("--{name} {v}: {e}")
            }),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        self.parsed(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        self.parsed(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        self.parsed(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()).collect())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("infer extra --model mnist --batch 32 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("infer"));
        assert_eq!(a.get("model"), Some("mnist"));
        assert_eq!(a.usize_or("batch", 0).unwrap(), 32);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn eq_form() {
        let a = parse("bench --in-bits=4 --scale=0.5");
        assert_eq!(a.usize_or("in-bits", 0).unwrap(), 4);
        assert!((a.f64_or("scale", 0.0).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(!a.flag("nope"));
    }

    #[test]
    fn unparsable_present_value_is_an_error() {
        let a = parse("x --batch 3O --scale nope");
        let e = a.usize_or("batch", 1).unwrap_err().to_string();
        assert!(e.contains("--batch 3O"), "{e}");
        assert!(a.f64_or("scale", 1.0).is_err());
        assert!(a.u64_or("seed", 1).is_ok());
    }
}
