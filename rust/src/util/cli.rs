//! Tiny CLI argument parser (clap is not available offline).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] ...`

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Args {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.options.insert(name.to_string(), v);
                } else {
                    a.flags.push(name.to_string());
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok);
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()).collect())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("infer extra --model mnist --batch 32 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("infer"));
        assert_eq!(a.get("model"), Some("mnist"));
        assert_eq!(a.usize_or("batch", 0), 32);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn eq_form() {
        let a = parse("bench --in-bits=4 --scale=0.5");
        assert_eq!(a.usize_or("in-bits", 0), 4);
        assert!((a.f64_or("scale", 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(!a.flag("nope"));
    }
}
