"""AOT compiler: lower the L2 chip-mode graphs to HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
HLO text, compiles it on the PJRT CPU client and executes it on the
request path -- python never runs at inference time.

Interchange notes (see /opt/xla-example/README.md):
  * HLO *text*, not serialized HloModuleProto (jax >= 0.5 emits 64-bit
    instruction ids that xla_extension 0.5.1 rejects; the text parser
    reassigns ids).
  * lowered with return_tuple=True; rust unwraps with ``to_tuple1()``.
  * weights / golden vectors travel as .npz (the xla crate reads npz).

Emitted artifacts:
  cim_mvm_<ib>b<ob>b[_<act>]_r<R>c<C>b<B>.hlo.txt   single-core CIM MVM
  mnist_cnn7_b<B>.hlo.txt                           full CNN chip forward
  lstm_step_b<B>.hlo.txt                            one LSTM cell time-step
  rbm_gibbs_b<B>.hlo.txt                            one RBM Gibbs cycle
  golden.npz                                        parity test vectors
  manifest.json                                     parameter order/shapes
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from .cimcfg import CimConfig, device_constants
from .kernels import ref
from .kernels.mvm import cim_mvm_pallas


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_and_write(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return text


def spec_of(x):
    return jax.ShapeDtypeStruct(np.shape(x), jnp.float32)


# --------------------------------------------------------------------------
# Artifact builders
# --------------------------------------------------------------------------

def build_mvm_artifacts(out_dir, manifest, golden):
    """Single-core CIM MVM executables over the chip's precision range."""
    batch, rows, cols = 32, 128, 256
    variants = [
        (2, 8, "none"), (4, 8, "none"), (6, 8, "none"),
        (4, 8, "relu"), (4, 4, "none"), (2, 1, "stochastic"),
    ]
    rng = np.random.default_rng(42)
    for ib, ob, act in variants:
        cfg = CimConfig(rows=rows, cols=cols, input_bits=ib, output_bits=ob,
                        activation=act)
        name = f"cim_mvm_{ib}b{ob}b_{act}_r{rows}c{cols}b{batch}"

        if act == "stochastic":
            def fn(x, gp, gn, noise, cfg=cfg):
                return (cim_mvm_pallas(x, gp, gn, cfg, noise=noise),)
            args = [spec_of(np.zeros((batch, rows))),
                    spec_of(np.zeros((rows, cols))),
                    spec_of(np.zeros((rows, cols))),
                    spec_of(np.zeros((batch, cols)))]
            params = [["x", [batch, rows]], ["g_pos", [rows, cols]],
                      ["g_neg", [rows, cols]], ["noise", [batch, cols]]]
        else:
            def fn(x, gp, gn, cfg=cfg):
                return (cim_mvm_pallas(x, gp, gn, cfg),)
            args = [spec_of(np.zeros((batch, rows))),
                    spec_of(np.zeros((rows, cols))),
                    spec_of(np.zeros((rows, cols)))]
            params = [["x", [batch, rows]], ["g_pos", [rows, cols]],
                      ["g_neg", [rows, cols]]]

        lower_and_write(fn, args, os.path.join(out_dir, name + ".hlo.txt"))
        manifest["artifacts"][name] = {
            "kind": "cim_mvm", "params": params,
            "outputs": [["y", [batch, cols]]],
            "cim_config": cfg.to_dict(),
        }

        # golden vectors for the 4b8b none variant (rust parity test)
        if (ib, ob, act) == (4, 8, "none"):
            w = rng.normal(size=(rows, cols)).astype(np.float32)
            gp, gn = ref.encode_differential(w, cfg.g_max_us, cfg.g_min_us)
            x = rng.integers(-7, 8, size=(batch, rows)).astype(np.float32)
            y = np.asarray(ref.cim_mvm_ref(x, gp, gn, cfg))
            golden["mvm_x"] = x
            golden["mvm_g_pos"] = np.asarray(gp)
            golden["mvm_g_neg"] = np.asarray(gn)
            golden["mvm_y"] = y
            manifest["golden"]["cim_mvm"] = {
                "artifact": name,
                "inputs": ["mvm_x", "mvm_g_pos", "mvm_g_neg"],
                "output": "mvm_y",
                "lsb_tolerance": 1,
            }


def build_mnist_artifact(out_dir, manifest, golden, batch=16, width=8):
    """Full MNIST CNN chip-mode forward with runtime conductances."""
    mdl = M.mnist_cnn7(width=width)
    n_layers = len(mdl.specs)
    names = [s.name for s in mdl.specs]

    def fn(x, *rest):
        gs = rest[:2 * n_layers]
        w_maxs = rest[2 * n_layers]
        shifts_v = rest[2 * n_layers + 1]
        chip, shifts = {}, {}
        for i, s in enumerate(mdl.specs):
            chip[s.name] = {"g_pos": gs[2 * i], "g_neg": gs[2 * i + 1],
                            "w_max": w_maxs[i], "n_bias_rows": 1}
            shifts[s.name] = shifts_v[i]
        return (mdl.chip_forward(x, chip, shifts, use_pallas=True),)

    params = [["x", [batch, 28, 28, 1]]]
    args = [spec_of(np.zeros((batch, 28, 28, 1)))]
    for s in mdl.specs:
        r = s.in_features + 1      # +1 forced bias row
        for g in ("g_pos", "g_neg"):
            params.append([f"{s.name}.{g}", [r, s.out_features]])
            args.append(spec_of(np.zeros((r, s.out_features))))
    params.append(["w_maxs", [n_layers]])
    args.append(spec_of(np.zeros(n_layers)))
    params.append(["shifts", [n_layers]])
    args.append(spec_of(np.zeros(n_layers)))

    name = f"mnist_cnn7_b{batch}"
    lower_and_write(fn, args, os.path.join(out_dir, name + ".hlo.txt"))
    manifest["artifacts"][name] = {
        "kind": "cnn_forward", "model": "mnist_cnn7",
        "params": params, "outputs": [["logits", [batch, 10]]],
        "layers": names,
        "layer_specs": [
            {"name": s.name, "kind": s.kind, "in_features": s.in_features,
             "out_features": s.out_features, "input_bits": s.input_bits,
             "activation": s.activation, "pool": s.pool,
             "in_channels": s.in_channels, "kh": s.kh, "kw": s.kw}
            for s in mdl.specs],
    }

    # Golden: random-init model, quantized random digits.
    params_f = mdl.init_params(3)
    chip = mdl.map_to_chip(params_f, force_bias_rows=1)
    imgs, _ = D.digits28(batch, seed=5)
    x = D.quantize_unsigned(imgs, 4)
    shifts = {s.name: 3.0 for s in mdl.specs}
    logits = mdl.chip_forward(x, chip, shifts, use_pallas=False)
    golden["mnist_x"] = np.asarray(x, np.float32)
    for s in mdl.specs:
        golden[f"mnist_{s.name}_g_pos"] = chip[s.name]["g_pos"]
        golden[f"mnist_{s.name}_g_neg"] = chip[s.name]["g_neg"]
    golden["mnist_w_maxs"] = np.array(
        [chip[s.name]["w_max"] for s in mdl.specs], np.float32)
    golden["mnist_shifts"] = np.array(
        [shifts[s.name] for s in mdl.specs], np.float32)
    golden["mnist_logits"] = np.asarray(logits, np.float32)
    manifest["golden"]["mnist_cnn7"] = {
        "artifact": name,
        "inputs": ["mnist_x"] +
                  sum([[f"mnist_{s.name}_g_pos", f"mnist_{s.name}_g_neg"]
                       for s in mdl.specs], []) +
                  ["mnist_w_maxs", "mnist_shifts"],
        "output": "mnist_logits",
        "rel_tolerance": 0.05,
    }


def build_lstm_artifact(out_dir, manifest, golden, batch=8, hidden=64,
                        input_dim=40):
    """One LSTM cell time-step; rust loops over time and cells."""
    mdl = M.speech_lstm(hidden=hidden, n_cells=1)
    rx = input_dim + 1          # + bias row
    rh = hidden

    def fn(x_t, h, c, gpx, gnx, gph, gnh, wmx, wmh):
        cell = {
            "wx": {"g_pos": gpx, "g_neg": gnx, "w_max": wmx,
                   "n_bias_rows": 1},
            "wh": {"g_pos": gph, "g_neg": gnh, "w_max": wmh,
                   "n_bias_rows": 0},
        }
        h2, c2 = mdl._cell_step(cell, x_t, h, c, use_pallas=True)
        return (h2, c2)

    args = [spec_of(np.zeros((batch, input_dim))),
            spec_of(np.zeros((batch, hidden))),
            spec_of(np.zeros((batch, hidden))),
            spec_of(np.zeros((rx, 4 * hidden))),
            spec_of(np.zeros((rx, 4 * hidden))),
            spec_of(np.zeros((rh, 4 * hidden))),
            spec_of(np.zeros((rh, 4 * hidden))),
            spec_of(np.zeros(())), spec_of(np.zeros(()))]
    name = f"lstm_step_b{batch}"
    lower_and_write(fn, args, os.path.join(out_dir, name + ".hlo.txt"))
    manifest["artifacts"][name] = {
        "kind": "lstm_step",
        "params": [["x_t", [batch, input_dim]], ["h", [batch, hidden]],
                   ["c", [batch, hidden]],
                   ["wx.g_pos", [rx, 4 * hidden]],
                   ["wx.g_neg", [rx, 4 * hidden]],
                   ["wh.g_pos", [rh, 4 * hidden]],
                   ["wh.g_neg", [rh, 4 * hidden]],
                   ["wx.w_max", []], ["wh.w_max", []]],
        "outputs": [["h_next", [batch, hidden]],
                    ["c_next", [batch, hidden]]],
        "hidden": hidden, "input_dim": input_dim,
    }

    # Golden
    ps = mdl.init_params(11)
    chip = mdl.map_to_chip(ps)
    cell = chip[0]
    # force single bias row shape for wx
    w_aug, _ = M.augment_with_bias(ps[0]["wx"]["w"], ps[0]["wx"]["b"], 7,
                                   force_rows=1)
    gp, gn, wm = M.layer_conductances(w_aug, mdl.g_max_us)
    cell["wx"] = {"g_pos": gp, "g_neg": gn, "w_max": wm, "n_bias_rows": 1}
    rng = np.random.default_rng(12)
    x_t = rng.integers(-7, 8, (batch, input_dim)).astype(np.float32)
    h = rng.integers(-7, 8, (batch, hidden)).astype(np.float32)
    c = rng.normal(size=(batch, hidden)).astype(np.float32)
    h2, c2 = mdl._cell_step(cell, x_t, h, c, use_pallas=False)
    golden.update({
        "lstm_x_t": x_t, "lstm_h": h, "lstm_c": c,
        "lstm_wx_g_pos": cell["wx"]["g_pos"],
        "lstm_wx_g_neg": cell["wx"]["g_neg"],
        "lstm_wh_g_pos": cell["wh"]["g_pos"],
        "lstm_wh_g_neg": cell["wh"]["g_neg"],
        "lstm_wx_w_max": np.float32(cell["wx"]["w_max"]),
        "lstm_wh_w_max": np.float32(cell["wh"]["w_max"]),
        "lstm_h_next": np.asarray(h2), "lstm_c_next": np.asarray(c2),
    })
    manifest["golden"]["lstm_step"] = {
        "artifact": name,
        "inputs": ["lstm_x_t", "lstm_h", "lstm_c", "lstm_wx_g_pos",
                   "lstm_wx_g_neg", "lstm_wh_g_pos", "lstm_wh_g_neg",
                   "lstm_wx_w_max", "lstm_wh_w_max"],
        "outputs": ["lstm_h_next", "lstm_c_next"],
        "rel_tolerance": 0.02,
    }


def build_rbm_artifact(out_dir, manifest, golden, batch=16):
    """One RBM Gibbs cycle (v -> h -> v), bidirectional MVM."""
    rbm = M.RbmModel()
    nv, nh = rbm.n_visible, rbm.n_hidden

    def fn(v, gp, gn, a, b, u1, u2):
        spec_f = M.CimLayerSpec(name="f", kind="dense", in_features=nv,
                                out_features=nh, input_bits=2,
                                activation="none", g_max_us=rbm.g_max_us)
        spec_b = M.CimLayerSpec(name="b", kind="dense", in_features=nh,
                                out_features=nv, input_bits=2,
                                activation="none", g_max_us=rbm.g_max_us)
        w_max = jnp.float32(1.0)
        act_h = M.cim_linear(v, gp, gn, spec_f, w_max, 0, use_pallas=True)
        p_h = jax.nn.sigmoid(8.0 * (act_h + b))
        h = (u1 < p_h).astype(jnp.float32)
        act_v = M.cim_linear(h, gp.T, gn.T, spec_b, w_max, 0,
                             use_pallas=True)
        p_v = jax.nn.sigmoid(8.0 * (act_v + a))
        v2 = (u2 < p_v).astype(jnp.float32)
        return (v2, h)

    args = [spec_of(np.zeros((batch, nv))), spec_of(np.zeros((nv, nh))),
            spec_of(np.zeros((nv, nh))), spec_of(np.zeros(nv)),
            spec_of(np.zeros(nh)), spec_of(np.zeros((batch, nh))),
            spec_of(np.zeros((batch, nv)))]
    name = f"rbm_gibbs_b{batch}"
    lower_and_write(fn, args, os.path.join(out_dir, name + ".hlo.txt"))
    manifest["artifacts"][name] = {
        "kind": "rbm_gibbs",
        "params": [["v", [batch, nv]], ["g_pos", [nv, nh]],
                   ["g_neg", [nv, nh]], ["a", [nv]], ["b", [nh]],
                   ["u1", [batch, nh]], ["u2", [batch, nv]]],
        "outputs": [["v_next", [batch, nv]], ["h", [batch, nh]]],
        "n_visible": nv, "n_hidden": nh,
    }


# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-models", action="store_true",
                    help="only emit the single-core MVM artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "device_constants": device_constants(),
        "artifacts": {},
        "golden": {},
    }
    golden = {}

    print("[aot] building CIM MVM artifacts...")
    build_mvm_artifacts(args.out_dir, manifest, golden)
    if not args.skip_models:
        print("[aot] building mnist_cnn7 artifact...")
        build_mnist_artifact(args.out_dir, manifest, golden)
        print("[aot] building lstm_step artifact...")
        build_lstm_artifact(args.out_dir, manifest, golden)
        print("[aot] building rbm_gibbs artifact...")
        build_rbm_artifact(args.out_dir, manifest, golden)

    np.savez(os.path.join(args.out_dir, "golden.npz"), **golden)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts to "
          f"{args.out_dir}")


if __name__ == "__main__":
    main()
