"""Shared compute-in-memory configuration for the NeuRRAM simulator.

Single source of truth for the device / circuit constants the paper
specifies (Methods).  The rust side mirrors these in
``rust/src/energy/params.rs`` and ``rust/src/device/rram.rs``; the
integration tests cross-check a JSON dump of this config against the rust
constants (see ``aot.py`` which embeds it in the artifact manifest).
"""

from dataclasses import dataclass, asdict, field


# --- RRAM device constants (paper Methods, "RRAM write-verify programming") ---
G_MIN_US = 1.0          # minimum conductance, micro-siemens
G_MAX_CNN_US = 40.0     # g_max used for CNNs
G_MAX_RNN_US = 30.0     # g_max used for LSTMs and RBMs
RELAX_SIGMA_PEAK_US = 3.87   # peak conductance-relaxation sigma (at ~12 uS)
RELAX_SIGMA_POST3_US = 2.0   # sigma after 3 iterative programming rounds
WRITE_ACCEPT_US = 1.0        # write-verify acceptance range (+/- 1 uS)

# --- Voltage-mode MVM constants ---
V_READ = 0.5            # read pulse amplitude (V), Methods "scaling" section
V_REF = 1.0             # virtual reference level; only deltas matter here

# --- ADC / neuron constants ---
N_MAX_DECREMENT = 128   # max charge-decrement steps => <= 8-bit signed output
# Piecewise-linear tanh/sigmoid compression break points (paper Methods):
# counter increments every 1 step until 35, every 2 until 40, every 3 until
# 43, every 4 afterwards.
TANH_PWL_BREAKS = (35, 40, 43)


@dataclass(frozen=True)
class CimConfig:
    """Configuration of a single CIM-core matrix-vector multiplication.

    rows/cols are *logical weight* dimensions: the physical array holds
    2*rows wires because every weight is a differential pair of RRAM cells
    on adjacent rows of the same column (paper Extended Data Fig. 3a).
    """

    rows: int = 128               # logical weight rows  (<= 128 per core)
    cols: int = 256               # output columns       (<= 256 per core)
    input_bits: int = 4           # 1..6  (signed; 1 => binary {0,1} special)
    output_bits: int = 8          # 1..8  (signed)
    g_max_us: float = G_MAX_CNN_US
    g_min_us: float = G_MIN_US
    v_read: float = V_READ
    # ADC LSB as a fraction of v_read. v_decr = adc_lsb_frac * v_read.
    adc_lsb_frac: float = 1.0 / 64.0
    activation: str = "none"      # none | relu | tanh | sigmoid | stochastic
    # First-order driver IR-drop coefficient: effective read voltage is
    # v_read / (1 + ir_alpha * sum_g_col / (2*rows*g_max)); 0 disables.
    ir_alpha: float = 0.0

    @property
    def v_decr(self) -> float:
        return self.adc_lsb_frac * self.v_read

    @property
    def out_mag_max(self) -> int:
        return min(2 ** (self.output_bits - 1) - 1, N_MAX_DECREMENT)

    @property
    def in_mag_max(self) -> int:
        return 2 ** (self.input_bits - 1) - 1 if self.input_bits > 1 else 1

    def to_dict(self) -> dict:
        d = asdict(self)
        d["v_decr"] = self.v_decr
        d["out_mag_max"] = self.out_mag_max
        d["in_mag_max"] = self.in_mag_max
        return d


def device_constants() -> dict:
    """Device-level constants embedded in the artifact manifest so the rust
    side can assert it was built against the same physics."""
    return {
        "g_min_us": G_MIN_US,
        "g_max_cnn_us": G_MAX_CNN_US,
        "g_max_rnn_us": G_MAX_RNN_US,
        "relax_sigma_peak_us": RELAX_SIGMA_PEAK_US,
        "relax_sigma_post3_us": RELAX_SIGMA_POST3_US,
        "write_accept_us": WRITE_ACCEPT_US,
        "v_read": V_READ,
        "n_max_decrement": N_MAX_DECREMENT,
        "tanh_pwl_breaks": list(TANH_PWL_BREAKS),
    }
