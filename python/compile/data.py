"""Dataset substrates.

This environment has no network access, so the paper's three public
benchmarks are substituted by procedural datasets with matching tensor
shapes and class structure (DESIGN.md §6).  Real data is used
automatically when present under ``data/`` (IDX or .npz), keeping every
downstream code path identical.

  * digits28   -- 28x28x1 grayscale digits (MNIST substitute): a 5x7
                  stroke font rendered with random shift / thickness /
                  pixel noise / elastic-ish jitter.
  * textures32 -- 32x32x3 10-class textures (CIFAR-10 substitute):
                  parametric generators (stripes, checks, blobs, rings,
                  gradients, ...) with random phase/frequency/color.
  * mfcc_cmds  -- 50x40 MFCC-like series, 12 classes (Google speech
                  commands substitute): class-specific time-frequency
                  trajectories (chirps/harmonics) + noise.

Mirrored in rust by ``rust/src/io/datasets.rs`` (same generators, same
class definitions) so both sides of the stack agree on the workload.
"""

import os

import numpy as np

# 5x7 bitmap font for digits 0-9 (rows top->bottom, '#' = on).
_FONT = {
    0: ["#####", "#...#", "#...#", "#...#", "#...#", "#...#", "#####"],
    1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", "#####"],
    2: ["#####", "....#", "....#", "#####", "#....", "#....", "#####"],
    3: ["#####", "....#", "....#", "#####", "....#", "....#", "#####"],
    4: ["#...#", "#...#", "#...#", "#####", "....#", "....#", "....#"],
    5: ["#####", "#....", "#....", "#####", "....#", "....#", "#####"],
    6: ["#####", "#....", "#....", "#####", "#...#", "#...#", "#####"],
    7: ["#####", "....#", "...#.", "..#..", ".#...", ".#...", ".#..."],
    8: ["#####", "#...#", "#...#", "#####", "#...#", "#...#", "#####"],
    9: ["#####", "#...#", "#...#", "#####", "....#", "....#", "#####"],
}


def _glyph(d: int) -> np.ndarray:
    return np.array([[c == "#" for c in row] for row in _FONT[d]], np.float32)


def digits28(n: int, seed: int = 0, noise: float = 0.15):
    """MNIST-substitute: n images [n,28,28,1] in [0,1] + labels [n]."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    imgs = np.zeros((n, 28, 28, 1), np.float32)
    for i, d in enumerate(labels):
        g = _glyph(int(d))
        sy = rng.integers(2, 4)   # vertical stroke scale
        sx = rng.integers(2, 4)
        up = np.kron(g, np.ones((sy, sx), np.float32))   # <=21 x <=15
        h, w = up.shape
        # random thickness: one dilation pass with prob 1/2
        if rng.random() < 0.5:
            pad = np.pad(up, 1)
            up = np.maximum(up, np.maximum(
                np.maximum(pad[:-2, 1:-1], pad[2:, 1:-1]),
                np.maximum(pad[1:-1, :-2], pad[1:-1, 2:])))
        oy = rng.integers(0, 28 - h + 1)
        ox = rng.integers(0, 28 - w + 1)
        img = np.zeros((28, 28), np.float32)
        img[oy:oy + h, ox:ox + w] = up
        img += rng.normal(0, noise, img.shape).astype(np.float32)
        imgs[i, :, :, 0] = np.clip(img, 0.0, 1.0)
    return imgs, labels.astype(np.int32)


def _texture(cls: int, rng) -> np.ndarray:
    """One 32x32x3 image for texture class 0..9."""
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32) / 32.0
    f = rng.uniform(2.0, 4.0)
    ph = rng.uniform(0, 2 * np.pi)
    base = {
        0: np.sin(2 * np.pi * f * xx + ph),                        # v-stripes
        1: np.sin(2 * np.pi * f * yy + ph),                        # h-stripes
        2: np.sin(2 * np.pi * f * (xx + yy) + ph),                 # diagonal
        3: np.sign(np.sin(2 * np.pi * f * xx + ph)
                   * np.sin(2 * np.pi * f * yy + ph)),             # checker
        4: np.sin(2 * np.pi * f * np.sqrt((xx - 0.5) ** 2
                                          + (yy - 0.5) ** 2) * 2), # rings
        5: xx * 2 - 1,                                             # x-gradient
        6: yy * 2 - 1,                                             # y-gradient
        7: np.sin(2 * np.pi * f * xx * yy * 4 + ph),               # hyperbolic
        8: np.cos(2 * np.pi * f * xx + ph) * np.cos(np.pi * f * yy),  # grid
        9: np.sin(2 * np.pi * (f * xx + f * 0.5 * xx * xx) + ph),  # chirp
    }[cls]
    img = np.zeros((32, 32, 3), np.float32)
    hue = rng.uniform(0.3, 1.0, size=3)
    for ch in range(3):
        img[:, :, ch] = 0.5 + 0.5 * base * hue[ch]
    return img


def textures32(n: int, seed: int = 0, noise: float = 0.08):
    """CIFAR-10-substitute: n images [n,32,32,3] in [0,1] + labels [n]."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    imgs = np.zeros((n, 32, 32, 3), np.float32)
    for i, c in enumerate(labels):
        img = _texture(int(c), rng)
        img += rng.normal(0, noise, img.shape).astype(np.float32)
        imgs[i] = np.clip(img, 0.0, 1.0)
    return imgs, labels.astype(np.int32)


def mfcc_cmds(n: int, seed: int = 0, t: int = 50, d: int = 40,
              n_classes: int = 12, noise: float = 0.35):
    """Speech-command substitute: [n, t, d] MFCC-like series + labels.

    Each class is a distinct time-frequency trajectory: a band whose
    centre sweeps with class-specific slope/curvature plus a class
    harmonic, roughly what MFCC energy of short spoken words looks like.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n)
    xs = np.zeros((n, t, d), np.float32)
    tt = np.linspace(0, 1, t)[:, None]
    dd = np.arange(d)[None, :].astype(np.float32)
    for i, c in enumerate(labels):
        c = int(c)
        slope = (c % 4 - 1.5) * 12.0
        curve = (c // 4 - 1.0) * 10.0
        centre = d / 2 + slope * (tt - 0.5) + curve * (tt - 0.5) ** 2 * 4
        width = 2.5 + (c % 3)
        band = np.exp(-((dd - centre) ** 2) / (2 * width ** 2))
        harm = 0.5 * np.exp(-((dd - (centre + d / 4) % d) ** 2)
                            / (2 * width ** 2))
        amp = np.sin(np.pi * tt.squeeze()) ** 0.5   # onset/offset envelope
        x = (band + harm) * amp[:, None]
        x += rng.normal(0, noise, x.shape) * 0.3
        xs[i] = x.astype(np.float32)
    # normalize to zero-mean unit-ish range like real MFCCs
    xs = (xs - xs.mean()) / (xs.std() + 1e-6)
    return xs, labels.astype(np.int32)


def quantize_unsigned(x, bits: int):
    """[0,1] floats -> unsigned ``bits`` integers (chip input format)."""
    m = 2 ** bits - 1
    return np.clip(np.round(np.asarray(x) * m), 0, m).astype(np.float32)


def quantize_signed(x, bits: int, clip_sigma: float = 2.5):
    """Zero-mean floats -> signed ``bits`` integers via sigma clipping."""
    m = 2 ** (bits - 1) - 1
    s = clip_sigma * np.std(x) + 1e-6
    return np.clip(np.round(np.asarray(x) / s * m), -m, m).astype(np.float32)


def load_or_generate(name: str, n: int, seed: int = 0, data_dir="../data"):
    """Prefer real data when present; otherwise procedural substitute."""
    path = os.path.join(data_dir, f"{name}.npz")
    if os.path.exists(path):
        z = np.load(path)
        return z["x"][:n], z["y"][:n]
    return {
        "digits28": digits28,
        "textures32": textures32,
        "mfcc_cmds": mfcc_cmds,
    }[name](n, seed=seed)
