"""Pure-jnp oracle for the NeuRRAM voltage-mode CIM MVM.

This is the *correctness contract* shared by three implementations:

  1. the Pallas kernel in ``mvm.py`` (asserted equal by pytest),
  2. the rust cycle-level core simulator (asserted equal via golden
     vectors exported by ``aot.py`` into the artifact manifest),
  3. the HLO artifacts executed by the rust PJRT runtime.

Physics being modelled (paper Fig. 2h + Methods):

  * every logical weight w is a differential pair of conductances on two
    adjacent rows of the same column:
        g+ = max(g_max * w / w_max, g_min)
        g- = max(-g_max * w / w_max, g_min)
  * during the input phase the two wires of a pair are driven to
    +/- x_i * V_read around V_ref, so the settled open-circuit voltage on
    output column j is the conductance-weighted average
        dV_j = V_read * sum_i x_i (g+_ij - g-_ij) / sum_i (g+_ij + g-_ij)
    -- the denominator is the paper's "automatic dynamic-range
    normalization" (Fig. 2i).
  * the neuron integrates dV over bit-serial input pulses (n-bit signed
    input => n-1 pulse phases with 2^k sampling cycles each), then
    converts by charge decrement: magnitude = number of V_decr steps
    until the comparator flips, with early stop at the configured
    maximum (N_max = 128 => at most 8-bit signed outputs).
"""

import jax.numpy as jnp
import numpy as np

from ..cimcfg import CimConfig, TANH_PWL_BREAKS


# --------------------------------------------------------------------------
# Weight -> differential conductance encoding
# --------------------------------------------------------------------------

def encode_differential(w, g_max_us: float, g_min_us: float, w_max=None):
    """Map real weights [R, C] to differential conductance pair (g+, g-).

    Matches paper Methods: g+ = max(g_max*W/w_max, g_min),
    g- = max(-g_max*W/w_max, g_min).  Returns conductances in micro-siemens.
    """
    w = jnp.asarray(w, jnp.float32)
    if w_max is None:
        w_max = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
    scaled = g_max_us * w / w_max
    g_pos = jnp.maximum(scaled, g_min_us)
    g_neg = jnp.maximum(-scaled, g_min_us)
    return g_pos, g_neg


def decode_differential(g_pos, g_neg, g_max_us: float, w_max: float = 1.0):
    """Inverse of :func:`encode_differential` (up to the g_min clamp)."""
    return (g_pos - g_neg) * (w_max / g_max_us)


# --------------------------------------------------------------------------
# Analog settling
# --------------------------------------------------------------------------

def settle_voltage(x, g_pos, g_neg, cfg: CimConfig):
    """Settled output-line voltage deviation from V_ref, for integer inputs.

    x: [B, R] signed integers (as float32), |x| <= cfg.in_mag_max
    g_pos, g_neg: [R, C] conductances in uS
    returns dV: [B, C] volts
    """
    x = jnp.asarray(x, jnp.float32)
    num = x @ (g_pos - g_neg)                      # [B, C], uS-weighted
    den = jnp.sum(g_pos + g_neg, axis=0)           # [C]
    v = cfg.v_read * num / den
    if cfg.ir_alpha > 0.0:
        # First-order driver/array IR drop: columns with larger total
        # conductance pull more current through the shared drivers and see a
        # reduced effective read voltage (paper non-idealities (i)-(iii)).
        full = 2.0 * g_pos.shape[0] * cfg.g_max_us
        v = v / (1.0 + cfg.ir_alpha * den / full)
    return v


# --------------------------------------------------------------------------
# Charge-decrement ADC + activation folding
# --------------------------------------------------------------------------

def _pwl_compress(k, mag_max):
    """Piecewise-linear tanh compression of the decrement counter.

    Counter increments every step until 35, every 2 steps until 40, every 3
    until 43, every 4 afterwards (paper Methods).  k is the raw (linear)
    step count; returns the compressed counter value.
    """
    b1, b2, b3 = TANH_PWL_BREAKS          # 35, 40, 43
    k1 = float(b1)                        # raw steps to reach counter b1
    k2 = k1 + 2.0 * (b2 - b1)             # every 2 steps
    k3 = k2 + 3.0 * (b3 - b2)             # every 3 steps
    c = jnp.where(
        k <= k1, k,
        jnp.where(
            k <= k2, b1 + jnp.floor((k - k1) / 2.0),
            jnp.where(
                k <= k3, b2 + jnp.floor((k - k2) / 3.0),
                b3 + jnp.floor((k - k3) / 4.0),
            ),
        ),
    )
    return jnp.minimum(c, float(mag_max))


def adc_quantize(v, cfg: CimConfig, noise=None):
    """Convert analog voltages to signed integer neuron outputs.

    Models the sign-bit comparison followed by charge-decrement magnitude
    counting: magnitude = floor(|v| / v_decr) clipped to out_mag_max
    (the comparator flips on the step whose cumulative decrement first
    exceeds |v|; the counter holds the number of completed steps).

    Activation folding (paper Methods):
      * relu       -- negative sign-bit skips decrements entirely => 0
      * tanh       -- counter increments follow the PWL schedule
      * sigmoid    -- tanh output renormalized to [0, mag_max]
      * stochastic -- LFSR noise added before the sign comparison; binary out
    """
    if noise is not None:
        v = v + noise
    if cfg.activation == "stochastic":
        return (v > 0.0).astype(jnp.float32)

    sign = jnp.sign(v)
    k = jnp.floor(jnp.abs(v) / cfg.v_decr)
    k = jnp.minimum(k, float(cfg.out_mag_max))

    if cfg.activation == "relu":
        return jnp.where(sign > 0, k, 0.0)
    if cfg.activation in ("tanh", "sigmoid"):
        c = _pwl_compress(k, cfg.out_mag_max)
        t = sign * c
        if cfg.activation == "sigmoid":
            # (tanh + mag_max) / 2, kept integral.
            return jnp.floor((t + cfg.out_mag_max) / 2.0)
        return t
    return sign * k


# --------------------------------------------------------------------------
# Full reference MVM
# --------------------------------------------------------------------------

def cim_mvm_ref(x, g_pos, g_neg, cfg: CimConfig, noise=None):
    """Reference voltage-mode CIM MVM: x [B,R] ints -> y [B,C] ints."""
    v = settle_voltage(x, g_pos, g_neg, cfg)
    return adc_quantize(v, cfg, noise=noise)


def mvm_scale(g_pos, g_neg, cfg: CimConfig, w_max: float):
    """Digital post-scale that undoes the analog normalization.

    y_int * mvm_scale ~= x @ w in real units: the voltage normalization
    divides by sum(g+ + g-) per column and the ADC divides by v_decr, so the
    inverse factor is  den * v_decr * w_max / (v_read * g_max).
    This is the paper's "pre-compute the normalization factor from the
    weight matrix and multiply it back after the ADC".
    """
    den = jnp.sum(g_pos + g_neg, axis=0)
    return den * cfg.v_decr * w_max / (cfg.v_read * cfg.g_max_us)


# --------------------------------------------------------------------------
# Bit-plane helpers (shared with the Pallas kernel's bit-serial schedule)
# --------------------------------------------------------------------------

def bit_planes(x, n_bits: int):
    """Decompose signed integers into magnitude bit-planes, MSB first.

    Mirrors the chip's input scheme: n-bit signed input => n-1 pulse phases;
    the phase carrying magnitude bit k is integrated 2^k cycles.
    Returns [n-1, B, R] float32 planes with values in {-1, 0, +1}.
    """
    x = np.asarray(x)
    sign = np.sign(x)
    mag = np.abs(x).astype(np.int64)
    planes = []
    for k in range(max(n_bits - 2, 0), -1, -1):
        planes.append(((mag >> k) & 1) * sign)
    return np.stack(planes, axis=0).astype(np.float32)
