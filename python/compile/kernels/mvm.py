"""Pallas kernel for the NeuRRAM voltage-mode CIM matrix-vector multiply.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's analog
array is weight-stationary -- the conductance matrix never moves, inputs
stream in bit-serially, and the ADC is a per-output epilogue.  The Pallas
expression of that schedule:

  * BlockSpec keeps a [R, bc] tile of each conductance matrix resident
    (the VMEM-resident "crossbar"),
  * the bit-serial input phase is an unrolled loop over magnitude
    bit-planes, each contributing a {-1,0,+1}-valued matmul weighted by
    its 2^k sampling-cycle count (an MXU-friendly GEMM per plane),
  * the charge-decrement ADC + activation folding is an element-wise
    epilogue on the settled voltages.

The kernel is lowered with ``interpret=True``: on this CPU-PJRT image a
real TPU lowering would emit a Mosaic custom-call the CPU client cannot
execute.  Numerics are identical either way; TPU efficiency estimates are
in DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..cimcfg import CimConfig, TANH_PWL_BREAKS


def _adc_epilogue(v, cfg: CimConfig, noise):
    """Charge-decrement ADC written with element-wise jnp ops.

    Must stay in exact lock-step with ``ref.adc_quantize`` -- pytest
    asserts bit-exact equality between the two.
    """
    if noise is not None:
        v = v + noise
    if cfg.activation == "stochastic":
        return (v > 0.0).astype(jnp.float32)

    sign = jnp.sign(v)
    k = jnp.floor(jnp.abs(v) / cfg.v_decr)
    k = jnp.minimum(k, float(cfg.out_mag_max))

    if cfg.activation == "relu":
        return jnp.where(sign > 0, k, 0.0)
    if cfg.activation in ("tanh", "sigmoid"):
        b1, b2, b3 = TANH_PWL_BREAKS
        k1 = float(b1)
        k2 = k1 + 2.0 * (b2 - b1)
        k3 = k2 + 3.0 * (b3 - b2)
        c = jnp.where(
            k <= k1, k,
            jnp.where(
                k <= k2, b1 + jnp.floor((k - k1) / 2.0),
                jnp.where(
                    k <= k3, b2 + jnp.floor((k - k2) / 3.0),
                    b3 + jnp.floor((k - k3) / 4.0),
                ),
            ),
        )
        c = jnp.minimum(c, float(cfg.out_mag_max))
        t = sign * c
        if cfg.activation == "sigmoid":
            return jnp.floor((t + cfg.out_mag_max) / 2.0)
        return t
    return sign * k


def _mvm_kernel(x_ref, gp_ref, gn_ref, o_ref, *, cfg: CimConfig,
                noise_ref=None):
    """One (batch-tile, column-tile) cell of the CIM MVM grid."""
    x = x_ref[...]                      # [bb, R] signed ints as f32
    gp = gp_ref[...]                    # [R, bc] uS
    gn = gn_ref[...]
    g_diff = gp - gn
    den = jnp.sum(gp + gn, axis=0)      # [bc] -- voltage-mode normalizer

    # ---- bit-serial input phase ------------------------------------------
    # n-bit signed input => n-1 pulse phases. The phase for magnitude bit k
    # is a ternary {-1,0,+1} drive integrated for 2^k sampling cycles; the
    # weighted sum of the per-plane settled voltages reconstructs the full
    # integer MVM (the analog system is linear in the drive voltage).
    sign = jnp.sign(x)
    mag = jnp.abs(x)
    n_planes = max(cfg.input_bits - 1, 1)
    acc = jnp.zeros((x.shape[0], gp.shape[1]), jnp.float32)
    for k in range(n_planes - 1, -1, -1):
        plane = jnp.mod(jnp.floor(mag / float(2 ** k)), 2.0) * sign
        acc = acc + float(2 ** k) * jnp.dot(
            plane, g_diff, preferred_element_type=jnp.float32)

    # ---- settling + normalization ----------------------------------------
    v = cfg.v_read * acc / den
    if cfg.ir_alpha > 0.0:
        full = 2.0 * gp.shape[0] * cfg.g_max_us
        v = v / (1.0 + cfg.ir_alpha * den / full)

    # ---- ADC / activation epilogue ---------------------------------------
    noise = noise_ref[...] if noise_ref is not None else None
    o_ref[...] = _adc_epilogue(v, cfg, noise)


def _pick_block(n: int, pref: int) -> int:
    """Largest divisor of n not exceeding pref (keeps the grid exact)."""
    b = min(n, pref)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("cfg",))
def cim_mvm_pallas(x, g_pos, g_neg, cfg: CimConfig, noise=None):
    """Voltage-mode CIM MVM on one core's conductance pair.

    x      : [B, R] signed integers (float32 storage), |x| <= in_mag_max
    g_pos  : [R, C] positive-branch conductances, uS
    g_neg  : [R, C] negative-branch conductances, uS
    noise  : optional [B, C] analog-domain noise (LFSR injection or
             read-noise), added before ADC conversion
    returns: [B, C] signed integer neuron outputs (float32 storage)
    """
    x = jnp.asarray(x, jnp.float32)
    g_pos = jnp.asarray(g_pos, jnp.float32)
    g_neg = jnp.asarray(g_neg, jnp.float32)
    b, r = x.shape
    _, c = g_pos.shape

    bb = _pick_block(b, 128)
    bc = _pick_block(c, 256)
    grid = (b // bb, c // bc)

    in_specs = [
        pl.BlockSpec((bb, r), lambda i, j: (i, 0)),
        pl.BlockSpec((r, bc), lambda i, j: (0, j)),
        pl.BlockSpec((r, bc), lambda i, j: (0, j)),
    ]
    args = [x, g_pos, g_neg]
    if noise is not None:
        in_specs.append(pl.BlockSpec((bb, bc), lambda i, j: (i, j)))
        args.append(jnp.asarray(noise, jnp.float32))
        kern = functools.partial(_kernel_with_noise, cfg=cfg)
    else:
        kern = functools.partial(_mvm_kernel, cfg=cfg)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(*args)


def _kernel_with_noise(x_ref, gp_ref, gn_ref, n_ref, o_ref, *, cfg):
    _mvm_kernel(x_ref, gp_ref, gn_ref, o_ref, cfg=cfg, noise_ref=n_ref)
