"""L2: JAX model layer built on the L1 CIM-MVM kernel.

Two forward modes share one parameter pytree:

  * ``chip`` mode -- the integer pipeline the NeuRRAM chip executes:
    activations are small signed/unsigned integers, every matmul runs
    through the voltage-mode CIM kernel (differential conductances,
    per-core-segment normalization + ADC), partial sums from row-split
    segments are de-normalized and accumulated digitally, and layer
    outputs are re-quantized by a per-layer power-of-two shift (the
    quantity model-driven calibration tunes).
  * ``train`` mode -- float forward with weight-noise injection and
    straight-through fake-quantization, used by
    ``train/noise_train.py`` (the paper's noise-resilient training).

The chip-mode graphs are what ``aot.py`` lowers to HLO; conductances are
runtime *parameters* so the rust coordinator can feed the actually
programmed (relaxed, noisy) device state into the same executable.
"""

import functools
from dataclasses import dataclass, field, replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .cimcfg import CimConfig, G_MAX_CNN_US, G_MAX_RNN_US, G_MIN_US
from .kernels import ref
from .kernels.mvm import cim_mvm_pallas

MAX_ROWS_PER_CORE = 128   # differential pairs per 256-row physical array
MAX_COLS_PER_CORE = 256


# ==========================================================================
# Layer spec
# ==========================================================================

@dataclass(frozen=True)
class CimLayerSpec:
    """Static description of one CIM-mapped layer (conv or dense)."""
    name: str
    kind: str                 # "conv" | "dense"
    in_features: int          # flattened H*W*I for conv
    out_features: int
    input_bits: int = 4      # activation precision entering this layer
    output_bits: int = 8     # ADC precision
    activation: str = "relu"  # folded neuron activation
    g_max_us: float = G_MAX_CNN_US
    # conv-only geometry
    kh: int = 3
    kw: int = 3
    stride: int = 1
    padding: str = "SAME"
    in_channels: int = 0
    out_channels: int = 0
    pool: int = 1             # max-pool factor applied after the layer

    def mvm_cfg(self, rows: int, ir_alpha: float = 0.0) -> CimConfig:
        return CimConfig(
            rows=rows, cols=self.out_features,
            input_bits=self.input_bits, output_bits=self.output_bits,
            g_max_us=self.g_max_us, g_min_us=G_MIN_US,
            activation=self.activation, ir_alpha=ir_alpha,
        )


def row_segments(n_rows: int, max_rows: int = MAX_ROWS_PER_CORE):
    """Split a conductance matrix's rows into per-core segments.

    Mirrors rust ``coordinator::mapping``: equal-ish chunks, each at most
    ``max_rows`` differential pairs.
    """
    n_seg = max(1, -(-n_rows // max_rows))
    base = n_rows // n_seg
    rem = n_rows % n_seg
    sizes = [base + (1 if i < rem else 0) for i in range(n_seg)]
    out, start = [], 0
    for s in sizes:
        out.append((start, start + s))
        start += s
    return out


# ==========================================================================
# Parameters <-> conductances
# ==========================================================================

def bias_rows_needed(b, w_max: float, in_mag: int) -> int:
    """Paper: if the bias range is B times the weight range, spread the
    bias over B rows driven at full-scale input."""
    if b is None:
        return 0
    mx = float(np.max(np.abs(np.asarray(b))))
    return max(1, int(np.ceil(mx / (w_max * max(in_mag, 1)) - 1e-9)))


def augment_with_bias(w, b, in_mag: int, force_rows=None):
    """Append bias rows to a weight matrix.

    Returns (w_aug [R+nb, C], n_bias_rows).  During MVM the bias rows are
    driven at the full-scale input value ``in_mag``.  ``force_rows`` pins
    the row count (AOT graphs need static shapes); the per-row bias weight
    is then clipped to the weight range, losing any overflow -- calibrated
    models keep biases well inside range.
    """
    w = np.asarray(w, np.float32)
    if b is None and force_rows is None:
        return w, 0
    if b is None:
        b = np.zeros(w.shape[1], np.float32)
    w_max = float(np.max(np.abs(w)))
    nb = force_rows if force_rows is not None else \
        bias_rows_needed(b, w_max, in_mag)
    per_row = np.asarray(b, np.float32) / (nb * max(in_mag, 1))
    if force_rows is not None:
        per_row = np.clip(per_row, -w_max, w_max)
    rows = np.tile(per_row[None, :], (nb, 1))
    return np.concatenate([w, rows], axis=0), nb


def layer_conductances(w_aug, g_max_us: float):
    """Encode an augmented weight matrix into (g+, g-, w_max)."""
    w_max = float(np.max(np.abs(w_aug)))
    gp, gn = ref.encode_differential(w_aug, g_max_us, G_MIN_US, w_max=w_max)
    return np.asarray(gp), np.asarray(gn), w_max


# ==========================================================================
# Chip-mode linear op (segmented CIM MVM + digital accumulation)
# ==========================================================================

def cim_linear(x_int, g_pos, g_neg, spec: CimLayerSpec, w_max: float,
               n_bias_rows: int, *, use_pallas: bool = True,
               ir_alpha: float = 0.0, noise=None):
    """Integer activations -> float pre-activation values.

    x_int : [B, R] signed ints (float32 storage); bias rows are appended
            internally at full drive.
    g_pos/g_neg : [R + nb, C] conductance pair.
    Returns float32 [B, C]: de-normalized, accumulated partial sums, i.e.
    approximately x_int @ w_aug-ish in weight units * in-scale.
    """
    b = x_int.shape[0]
    r_total = g_pos.shape[0]
    in_mag = 2 ** (spec.input_bits - 1) - 1 if spec.input_bits > 1 else 1
    if n_bias_rows > 0:
        ones = jnp.full((b, n_bias_rows), float(in_mag), jnp.float32)
        x_int = jnp.concatenate([x_int, ones], axis=1)

    # The neuron's folded nonlinearity must act on the *total* accumulated
    # value; per-segment ADC runs linear ("none") and the activation is
    # applied digitally after accumulation when a layer spans segments.
    segs = row_segments(r_total)
    mvm_act = spec.activation if len(segs) == 1 else "none"

    acc = jnp.zeros((b, g_pos.shape[1]), jnp.float32)
    for (lo, hi) in segs:
        cfg = CimConfig(
            rows=hi - lo, cols=spec.out_features,
            input_bits=spec.input_bits, output_bits=spec.output_bits,
            g_max_us=spec.g_max_us, activation=mvm_act, ir_alpha=ir_alpha,
        )
        gp_s, gn_s = g_pos[lo:hi], g_neg[lo:hi]
        xs = x_int[:, lo:hi]
        fn = cim_mvm_pallas if use_pallas else ref.cim_mvm_ref
        y = fn(xs, gp_s, gn_s, cfg, noise=noise)
        scale = ref.mvm_scale(gp_s, gn_s, cfg, w_max)
        acc = acc + y * scale
    if mvm_act == "none" and spec.activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    return acc


def requantize(y, shift: float, bits: int, signed: bool):
    """Digital re-quantization between layers: divide by 2^shift, floor,
    clip to the next layer's input range."""
    q = jnp.floor(y / (2.0 ** shift))
    if signed:
        m = 2 ** (bits - 1) - 1
        return jnp.clip(q, -m, m)
    return jnp.clip(q, 0, 2 ** bits - 1)


# ==========================================================================
# Convolution via im2col (the chip's flattening, Fig. 4c)
# ==========================================================================

def im2col(x, kh: int, kw: int, stride: int, padding: str):
    """x [B, H, W, C] -> patches [B, Ho, Wo, kh*kw*C].

    Patch element order is (kh, kw, C) flattened C-fastest, matching the
    rust-side conductance row order (models/conductance.rs).
    """
    patches = jax.lax.conv_general_dilated_patches(
        jnp.moveaxis(x, 3, 1),                 # NCHW
        (kh, kw), (stride, stride), padding,
    )                                          # [B, C*kh*kw, Ho, Wo]
    b, ckk, ho, wo = patches.shape
    c = x.shape[3]
    patches = patches.reshape(b, c, kh * kw, ho, wo)
    patches = jnp.moveaxis(patches, (3, 4), (1, 2))   # [B, Ho, Wo, C, khkw]
    patches = jnp.swapaxes(patches, 3, 4)             # [B, Ho, Wo, khkw, C]
    return patches.reshape(b, ho, wo, kh * kw * c)


def maxpool2(x, k: int):
    if k <= 1:
        return x
    b, h, w, c = x.shape
    x = x[:, : h // k * k, : w // k * k, :]
    x = x.reshape(b, h // k, k, w // k, k, c)
    return jnp.max(x, axis=(2, 4))


# ==========================================================================
# Model definitions
# ==========================================================================

@dataclass
class CnnModel:
    """A CIM-mapped CNN: a stack of conv layers + one dense head."""
    name: str
    input_hw: int
    input_ch: int
    specs: Sequence[CimLayerSpec]
    n_classes: int

    def init_params(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        params = {}
        for s in self.specs:
            fan_in = s.in_features
            std = float(np.sqrt(2.0 / fan_in))
            params[s.name] = {
                "w": rng.normal(0, std, size=(s.in_features, s.out_features)
                                ).astype(np.float32),
                "b": np.zeros((s.out_features,), np.float32),
            }
        return params

    # -------------------- chip-mode forward --------------------
    def chip_forward(self, x_img, chip_params, shifts, *, use_pallas=True,
                     ir_alpha=0.0):
        """x_img: [B, H, W, C] integer activations (already input-quantized).
        chip_params[name] = dict(g_pos, g_neg, w_max, n_bias_rows).
        shifts[name] = requantization shift (calibrated).
        Returns logits [B, n_classes] (float, de-normalized)."""
        x = jnp.asarray(x_img, jnp.float32)
        for i, s in enumerate(self.specs):
            p = chip_params[s.name]
            last = i == len(self.specs) - 1
            next_bits = self.specs[i + 1].input_bits if not last else 0
            if s.kind == "conv":
                cols = im2col(x, s.kh, s.kw, s.stride, s.padding)
                b, ho, wo, r = cols.shape
                y = cim_linear(cols.reshape(b * ho * wo, r), p["g_pos"],
                               p["g_neg"], s, p["w_max"], p["n_bias_rows"],
                               use_pallas=use_pallas, ir_alpha=ir_alpha)
                y = y.reshape(b, ho, wo, s.out_features)
                y = maxpool2(y, s.pool)
                # unsigned activations live in the positive half of the
                # next layer's signed input range: clip at 2^(n-1)-1
                x = requantize(y, shifts[s.name], next_bits - 1,
                               signed=False)
            else:
                b = x.shape[0]
                y = cim_linear(x.reshape(b, -1), p["g_pos"], p["g_neg"], s,
                               p["w_max"], p["n_bias_rows"],
                               use_pallas=use_pallas, ir_alpha=ir_alpha)
                if last:
                    return y
                x = requantize(y, shifts[s.name], next_bits - 1,
                               signed=False)
        return x

    # -------------------- train-mode forward --------------------
    def train_forward(self, x_img, params, *, noise_frac=0.0, rng=None,
                      act_bits=3):
        """Float forward with weight-noise injection + STE activation
        fake-quant (PACT-style clipping at a fixed learned-ish alpha)."""
        x = jnp.asarray(x_img, jnp.float32)
        for i, s in enumerate(self.specs):
            w = params[s.name]["w"]
            bta = params[s.name]["b"]
            if noise_frac > 0.0 and rng is not None:
                rng, sub = jax.random.split(rng)
                w_max = jnp.max(jnp.abs(w))
                w = w + jax.random.normal(sub, w.shape) * (noise_frac * w_max)
            last = i == len(self.specs) - 1
            if s.kind == "conv":
                cols = im2col(x, s.kh, s.kw, s.stride, s.padding)
                y = cols @ w.reshape(s.in_features, s.out_features) + bta
                y = maxpool2(jnp.maximum(y, 0.0), s.pool)
                x = fake_quant_unsigned(y, act_bits)
            else:
                b = x.shape[0]
                y = x.reshape(b, -1) @ w + bta
                if last:
                    return y
                x = fake_quant_unsigned(jnp.maximum(y, 0.0), act_bits)
        return x

    def map_to_chip(self, params, force_bias_rows=None):
        """Float params -> conductance dicts (ideal, pre-programming)."""
        chip = {}
        for s in self.specs:
            in_mag = 2 ** (s.input_bits - 1) - 1 if s.input_bits > 1 else 1
            w_aug, nb = augment_with_bias(params[s.name]["w"],
                                          params[s.name]["b"], in_mag,
                                          force_rows=force_bias_rows)
            gp, gn, w_max = layer_conductances(w_aug, s.g_max_us)
            chip[s.name] = {"g_pos": gp, "g_neg": gn, "w_max": w_max,
                            "n_bias_rows": nb}
        return chip


def fake_quant_unsigned(y, bits: int):
    """STE fake-quantization to unsigned ``bits``.

    The clip range tracks the batch's 99.5th-percentile activation
    (stop-gradient), mirroring the chip's model-driven calibration where
    the requantization shift is chosen so the measured activation
    distribution fills the next layer's input range."""
    # mean + 3 sigma ~ p99.7 of the positive tail (percentile ops don't
    # lower cleanly on this jax/jaxlib build)
    alpha = jax.lax.stop_gradient(
        jnp.maximum(jnp.mean(y) + 3.0 * jnp.std(y), 1e-3))
    q = jnp.clip(y, 0.0, alpha)
    scale = alpha / (2 ** bits - 1)
    qq = jnp.round(q / scale) * scale
    return q + jax.lax.stop_gradient(qq - q)


# --------------------------------------------------------------------------
# Built-in model zoo (paper Table 1, CPU-budget-scaled: see DESIGN.md §6)
# --------------------------------------------------------------------------

def mnist_cnn7(width: int = 8) -> CnnModel:
    """7-layer CNN for 28x28 digits: 6 conv + 1 dense (paper MNIST model)."""
    w1, w2, w3 = width, 2 * width, 4 * width
    chans = [(1, w1), (w1, w1), (w1, w2), (w2, w2), (w2, w3), (w3, w3)]
    pools = [1, 2, 1, 2, 1, 2]
    specs = []
    for i, ((ci, co), p) in enumerate(zip(chans, pools)):
        # paper: "3-b unsigned" activations ([0,7]) and a "4-b unsigned"
        # input image ([0,15]); the chip's bit-serial input scheme is
        # signed (n-1 magnitude planes), so an n-b-unsigned activation
        # occupies the positive half of an (n+1)-bit signed input.
        specs.append(CimLayerSpec(
            name=f"conv{i + 1}", kind="conv",
            in_features=9 * ci, out_features=co,
            input_bits=4 if i else 5, activation="relu",
            in_channels=ci, out_channels=co, pool=p,
        ))
    specs.append(CimLayerSpec(
        name="fc", kind="dense",
        in_features=3 * 3 * w3,       # 28 -> 14 -> 7 -> 3 after three pools
        out_features=10, input_bits=4, activation="none",
    ))
    return CnnModel("mnist_cnn7", 28, 1, specs, 10)


def cifar_resnet(width: int = 8, blocks_per_stage: int = 3) -> CnnModel:
    """ResNet-20-shaped CNN for 32x32x3: 1 input conv + 3 stages x
    blocks_per_stage x 2 convs + dense head = 20 weight layers at the
    default. Skip connections are folded away -- the chip executes it as a
    plain conv stack (see DESIGN.md §6 on the CPU-budget variant)."""
    specs = [CimLayerSpec(
        name="conv_in", kind="conv", in_features=27, out_features=width,
        input_bits=5, activation="relu", in_channels=3, out_channels=width)]
    idx = 1
    cur = width
    for stage in range(3):
        out = width * (2 ** stage)
        for blk in range(blocks_per_stage):
            for half in range(2):
                # downsample (pool) on the first conv of stages 1 and 2
                pool = 2 if (stage > 0 and blk == 0 and half == 0) else 1
                specs.append(CimLayerSpec(
                    name=f"conv{idx}", kind="conv",
                    in_features=9 * cur, out_features=out,
                    input_bits=4, activation="relu",
                    in_channels=cur, out_channels=out, pool=pool))
                cur = out
                idx += 1
    final_hw = 32 // 4  # two pooled downsamples
    specs.append(CimLayerSpec(
        name="fc", kind="dense", in_features=final_hw * final_hw * cur,
        out_features=10, input_bits=4, activation="none"))
    return CnnModel("cifar_resnet", 32, 3, specs, 10)


# --------------------------------------------------------------------------
# LSTM (paper: 4 parallel cells, Google speech commands)
# --------------------------------------------------------------------------

@dataclass
class LstmModel:
    name: str
    n_cells: int = 4
    input_dim: int = 40
    hidden: int = 64
    n_classes: int = 12
    time_steps: int = 50
    input_bits: int = 4
    g_max_us: float = G_MAX_RNN_US

    def spec_x(self):
        return CimLayerSpec(
            name="wx", kind="dense", in_features=self.input_dim,
            out_features=4 * self.hidden, input_bits=self.input_bits,
            activation="none", g_max_us=self.g_max_us)

    def spec_h(self):
        return CimLayerSpec(
            name="wh", kind="dense", in_features=self.hidden,
            out_features=4 * self.hidden, input_bits=self.input_bits,
            activation="none", g_max_us=self.g_max_us)

    def spec_out(self):
        return CimLayerSpec(
            name="wo", kind="dense", in_features=self.hidden,
            out_features=self.n_classes, input_bits=self.input_bits,
            activation="none", g_max_us=self.g_max_us)

    def init_params(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        ps = []
        for c in range(self.n_cells):
            sx = np.sqrt(1.0 / self.input_dim)
            sh = np.sqrt(1.0 / self.hidden)
            ps.append({
                "wx": {"w": rng.normal(0, sx, (self.input_dim, 4 * self.hidden)).astype(np.float32),
                       "b": np.zeros(4 * self.hidden, np.float32)},
                "wh": {"w": rng.normal(0, sh, (self.hidden, 4 * self.hidden)).astype(np.float32),
                       "b": None},
                "wo": {"w": rng.normal(0, sh, (self.hidden, self.n_classes)).astype(np.float32),
                       "b": np.zeros(self.n_classes, np.float32)},
            })
        return ps

    def map_to_chip(self, params):
        chip = []
        for c in range(self.n_cells):
            cell = {}
            for key, spec in (("wx", self.spec_x()), ("wh", self.spec_h()),
                              ("wo", self.spec_out())):
                in_mag = 2 ** (spec.input_bits - 1) - 1
                w_aug, nb = augment_with_bias(params[c][key]["w"],
                                              params[c][key]["b"], in_mag)
                gp, gn, w_max = layer_conductances(w_aug, spec.g_max_us)
                cell[key] = {"g_pos": gp, "g_neg": gn, "w_max": w_max,
                             "n_bias_rows": nb}
            chip.append(cell)
        return chip

    def _cell_step(self, cell_chip, x_t, h, c, *, use_pallas):
        """One LSTM time step in chip mode: two CIM MVMs + digital gates."""
        gx = cim_linear(x_t, cell_chip["wx"]["g_pos"], cell_chip["wx"]["g_neg"],
                        self.spec_x(), cell_chip["wx"]["w_max"],
                        cell_chip["wx"]["n_bias_rows"], use_pallas=use_pallas)
        gh = cim_linear(h, cell_chip["wh"]["g_pos"], cell_chip["wh"]["g_neg"],
                        self.spec_h(), cell_chip["wh"]["w_max"],
                        cell_chip["wh"]["n_bias_rows"], use_pallas=use_pallas)
        gates = gx + gh
        hs = self.hidden
        i = jax.nn.sigmoid(gates[:, 0:hs])
        f = jax.nn.sigmoid(gates[:, hs:2 * hs])
        g = jnp.tanh(gates[:, 2 * hs:3 * hs])
        o = jax.nn.sigmoid(gates[:, 3 * hs:4 * hs])
        c_new = f * c + i * g
        h_new = o * jnp.tanh(c_new)
        return h_new, c_new

    def chip_forward(self, x_seq, chip_params, *, use_pallas=True):
        """x_seq: [B, T, input_dim] integer MFCC features (4-bit signed).
        Returns logits [B, n_classes] = sum over the parallel cells."""
        bsz = x_seq.shape[0]
        m = 2 ** (self.input_bits - 1) - 1
        logits = jnp.zeros((bsz, self.n_classes), jnp.float32)
        for cchip in chip_params:
            h = jnp.zeros((bsz, self.hidden), jnp.float32)
            c = jnp.zeros((bsz, self.hidden), jnp.float32)
            for t in range(self.time_steps):
                hq = jnp.clip(jnp.round(h * m), -m, m)   # 4-bit hidden state
                h, c = self._cell_step(cchip, x_seq[:, t, :], hq, c,
                                       use_pallas=use_pallas)
            hq = jnp.clip(jnp.round(h * m), -m, m)
            y = cim_linear(hq, cchip["wo"]["g_pos"], cchip["wo"]["g_neg"],
                           self.spec_out(), cchip["wo"]["w_max"],
                           cchip["wo"]["n_bias_rows"], use_pallas=use_pallas)
            logits = logits + y
        return logits

    def train_forward(self, x_seq, params, *, noise_frac=0.0, rng=None):
        """Float forward with weight-noise injection (training oracle)."""
        bsz = x_seq.shape[0]
        logits = jnp.zeros((bsz, self.n_classes), jnp.float32)
        for cp in params:
            wx, bx = cp["wx"]["w"], cp["wx"]["b"]
            wh = cp["wh"]["w"]
            wo, bo = cp["wo"]["w"], cp["wo"]["b"]
            if noise_frac > 0.0 and rng is not None:
                rng, k1, k2, k3 = jax.random.split(rng, 4)
                wx = wx + jax.random.normal(k1, wx.shape) * noise_frac * jnp.max(jnp.abs(wx))
                wh = wh + jax.random.normal(k2, wh.shape) * noise_frac * jnp.max(jnp.abs(wh))
                wo = wo + jax.random.normal(k3, wo.shape) * noise_frac * jnp.max(jnp.abs(wo))
            h = jnp.zeros((bsz, self.hidden), jnp.float32)
            c = jnp.zeros((bsz, self.hidden), jnp.float32)
            hs = self.hidden
            for t in range(self.time_steps):
                gates = x_seq[:, t, :] @ wx + bx + h @ wh
                i = jax.nn.sigmoid(gates[:, 0:hs])
                f = jax.nn.sigmoid(gates[:, hs:2 * hs])
                g = jnp.tanh(gates[:, 2 * hs:3 * hs])
                o = jax.nn.sigmoid(gates[:, 3 * hs:4 * hs])
                c = f * c + i * g
                h = o * jnp.tanh(c)
            logits = logits + h @ wo + bo
        return logits


def speech_lstm(hidden: int = 64, n_cells: int = 4) -> LstmModel:
    return LstmModel("speech_lstm", n_cells=n_cells, hidden=hidden)


# --------------------------------------------------------------------------
# RBM (paper: 794 visible x 120 hidden, Gibbs sampling image recovery)
# --------------------------------------------------------------------------

@dataclass
class RbmModel:
    name: str = "image_rbm"
    n_visible: int = 794      # 784 pixels + 10 one-hot labels
    n_hidden: int = 120
    g_max_us: float = G_MAX_RNN_US

    def init_params(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        return {
            "w": rng.normal(0, 0.05, (self.n_visible, self.n_hidden)).astype(np.float32),
            "a": np.zeros(self.n_visible, np.float32),   # visible bias
            "b": np.zeros(self.n_hidden, np.float32),    # hidden bias
        }

    def map_to_chip(self, params):
        gp, gn, w_max = layer_conductances(params["w"], self.g_max_us)
        return {"g_pos": gp, "g_neg": gn, "w_max": w_max,
                "a": np.asarray(params["a"]), "b": np.asarray(params["b"])}

    def gibbs_step(self, v, chip, key, *, use_pallas=True, beta=8.0):
        """One v->h->v Gibbs cycle using bidirectional MVM (TNSA forward +
        backward pass on the same conductance array).

        The stochastic neuron samples with LFSR noise: on-chip the noise is
        injected into the integrator; here the logistic sampling is done by
        comparing the sigmoid argument against logistic noise.
        """
        spec_f = CimLayerSpec(name="rbm_f", kind="dense",
                              in_features=self.n_visible,
                              out_features=self.n_hidden, input_bits=2,
                              activation="none", g_max_us=self.g_max_us)
        spec_b = CimLayerSpec(name="rbm_b", kind="dense",
                              in_features=self.n_hidden,
                              out_features=self.n_visible, input_bits=2,
                              activation="none", g_max_us=self.g_max_us)
        k1, k2 = jax.random.split(key)
        # forward: SL->BL direction
        act_h = cim_linear(v, chip["g_pos"], chip["g_neg"], spec_f,
                           chip["w_max"], 0, use_pallas=use_pallas)
        p_h = jax.nn.sigmoid(beta * (act_h + chip["b"]))
        h = (jax.random.uniform(k1, p_h.shape) < p_h).astype(jnp.float32)
        # backward: BL->SL direction, transposed conductances
        act_v = cim_linear(h, chip["g_pos"].T, chip["g_neg"].T, spec_b,
                           chip["w_max"], 0, use_pallas=use_pallas)
        p_v = jax.nn.sigmoid(beta * (act_v + chip["a"]))
        v_new = (jax.random.uniform(k2, p_v.shape) < p_v).astype(jnp.float32)
        return v_new, h

    def recover(self, v0, known_mask, chip, key, n_cycles: int = 10,
                *, use_pallas=True):
        """Paper's image-recovery procedure: Gibbs cycles, resetting the
        uncorrupted (known) pixels after each cycle."""
        v = v0
        for _ in range(n_cycles):
            key, sub = jax.random.split(key)
            v, _ = self.gibbs_step(v, chip, sub, use_pallas=use_pallas)
            v = jnp.where(known_mask > 0, v0, v)
        return v
