"""Chip-in-the-loop progressive fine-tuning (paper Fig. 3d/3f, ED Fig. 7a).

Weights are programmed onto the (simulated) chip one layer at a time.
After programming layer n, the *measured* outputs of layers 1..n on the
training set become the inputs used to fine-tune the still-in-software
layers n+1..N.  Non-linear hardware errors (IR drop, ADC clipping,
relaxation) of programmed layers are thereby compensated by the remaining
layers' universal-approximation capacity -- no weight reprogramming.

Test-set data is never used for training or checkpoint selection.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as M
from . import noise_train as NT


def chip_layer_forward(mdl, spec_idx, chip_layer, shifts, x, *, ir_alpha):
    """Measured (chip-mode) execution of one layer on integer inputs."""
    s = mdl.specs[spec_idx]
    last = spec_idx == len(mdl.specs) - 1
    next_bits = mdl.specs[spec_idx + 1].input_bits if not last else 4
    p = chip_layer
    if s.kind == "conv":
        cols = M.im2col(x, s.kh, s.kw, s.stride, s.padding)
        b, ho, wo, r = cols.shape
        y = M.cim_linear(cols.reshape(b * ho * wo, r), p["g_pos"], p["g_neg"],
                         s, p["w_max"], p["n_bias_rows"], use_pallas=False,
                         ir_alpha=ir_alpha)
        y = y.reshape(b, ho, wo, s.out_features)
        y = M.maxpool2(y, s.pool)
        return M.requantize(y, shifts[s.name], next_bits - 1, signed=False)
    y = M.cim_linear(x.reshape(x.shape[0], -1), p["g_pos"], p["g_neg"], s,
                     p["w_max"], p["n_bias_rows"], use_pallas=False,
                     ir_alpha=ir_alpha)
    if last:
        return y
    return M.requantize(y, shifts[s.name], next_bits - 1, signed=False)


def float_suffix(mdl, params, feats, from_idx, *, noise_frac=0.0, rng=None,
                 act_bits=3):
    """Software forward of layers from_idx..N on chip-measured features.

    Chip features are integers in [0, 2^bits-1]; rescale to the float
    model's activation range (PACT alpha = 6.0) so representations line up.
    """
    x = jnp.asarray(feats, jnp.float32)
    if from_idx < len(mdl.specs):
        bits = mdl.specs[from_idx].input_bits
        x = x * (6.0 / (2 ** (bits - 1) - 1))
    for i in range(from_idx, len(mdl.specs)):
        s = mdl.specs[i]
        w = params[s.name]["w"]
        bta = params[s.name]["b"]
        if noise_frac > 0.0 and rng is not None:
            rng, sub = jax.random.split(rng)
            w = w + jax.random.normal(sub, w.shape) * \
                (noise_frac * jnp.max(jnp.abs(w)))
        last = i == len(mdl.specs) - 1
        if s.kind == "conv":
            cols = M.im2col(x, s.kh, s.kw, s.stride, s.padding)
            y = cols @ w.reshape(s.in_features, s.out_features) + bta
            y = M.maxpool2(jnp.maximum(y, 0.0), s.pool)
            x = M.fake_quant_unsigned(y, act_bits)
        else:
            y = x.reshape(x.shape[0], -1) @ w + bta
            if last:
                return y
            x = M.fake_quant_unsigned(jnp.maximum(y, 0.0), act_bits)
    return x


def hybrid_accuracy(mdl, params, chip_params, shifts, programmed_upto,
                    x_int, y, *, ir_alpha, batch=64):
    """Accuracy with layers < programmed_upto measured on chip and the
    rest in software (Fig. 3f evaluation protocol)."""
    correct = 0
    for i in range(0, x_int.shape[0], batch):
        feats = jnp.asarray(x_int[i:i + batch], jnp.float32)
        for li in range(programmed_upto):
            feats = chip_layer_forward(mdl, li, chip_params[mdl.specs[li].name],
                                       shifts, feats, ir_alpha=ir_alpha)
        logits = float_suffix(mdl, params, feats, programmed_upto) \
            if programmed_upto < len(mdl.specs) else feats
        correct += int(jnp.sum(jnp.argmax(logits, 1) == y[i:i + batch]))
    return correct / x_int.shape[0]


def finetune_suffix(mdl, params, feats, labels, from_idx, *, epochs=3,
                    batch=32, lr=1e-4, noise_frac=0.1, seed=0):
    """Fine-tune layers from_idx..N on chip-measured features."""
    key = jax.random.PRNGKey(seed)
    opt = NT.adam_init(params)
    n = feats.shape[0]
    feats = jnp.asarray(feats)
    labels = jnp.asarray(labels)

    def loss_fn(p, xb, yb, k):
        logits = float_suffix(mdl, p, xb, from_idx, noise_frac=noise_frac,
                              rng=k)
        return NT.cross_entropy(logits, yb)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for s in range(max(1, n // batch)):
            idx = perm[s * batch:(s + 1) * batch]
            key, sub = jax.random.split(key)
            _, grads = grad_fn(params, feats[idx], labels[idx], sub)
            # freeze programmed layers: zero their grads
            for li in range(from_idx):
                name = mdl.specs[li].name
                grads[name] = jax.tree_util.tree_map(
                    lambda g: jnp.zeros_like(g) if g is not None else None,
                    grads[name])
            params, opt = NT.adam_step(params, grads, opt, lr=lr)
    return params


def progressive_finetune(mdl, params0, x_train, y_train, x_test, y_test, *,
                         relax_sigma=2.0, ir_alpha=0.3, epochs=2, lr=1e-4,
                         noise_frac=0.1, seed=0, log=print):
    """Full Fig. 3f experiment.

    Returns (acc_with_ft, acc_without_ft): test accuracy after each layer
    is programmed, with and without fine-tuning the remaining layers.
    """
    n_layers = len(mdl.specs)
    m_in = 2 ** (mdl.specs[0].input_bits) - 1

    # Two parameter tracks evolve: fine-tuned vs frozen baseline.
    params_ft = jax.tree_util.tree_map(
        lambda p: jnp.array(p) if p is not None else None, params0)
    params_fz = params_ft

    def program(params, seed_off):
        chip = mdl.map_to_chip(
            jax.tree_util.tree_map(
                lambda p: np.asarray(p) if p is not None else None, params))
        chip = NT.apply_relaxation(chip, sigma_us=relax_sigma,
                                   seed=seed + seed_off)
        return chip

    acc_ft, acc_fz = [], []
    chip_ft = {}
    chip_fz = program(params_fz, 0)
    shifts_fz = NT.calibrate_shifts(mdl, chip_fz, x_train[:64])
    feats = jnp.asarray(x_train, jnp.float32)

    for li in range(n_layers):
        name = mdl.specs[li].name
        # Program layer li using the *current* fine-tuned weights.
        chip_li = program(params_ft, 100 + li)[name]
        chip_ft[name] = chip_li
        shifts_ft = NT.calibrate_shifts(mdl, {**chip_fz, **chip_ft},
                                        x_train[:64])
        # Measure training-set features through the newly programmed layer.
        feats = chip_layer_forward(mdl, li, chip_li, shifts_ft, feats,
                                   ir_alpha=ir_alpha)
        # Fine-tune the remaining software layers on measured features.
        if li + 1 < n_layers:
            params_ft = finetune_suffix(mdl, params_ft, feats, y_train,
                                        li + 1, epochs=epochs, lr=lr,
                                        noise_frac=noise_frac, seed=seed + li)
        a_ft = hybrid_accuracy(mdl, params_ft, chip_ft, shifts_ft, li + 1,
                               x_test, y_test, ir_alpha=ir_alpha)
        a_fz = hybrid_accuracy(mdl, params_fz, chip_fz, shifts_fz, li + 1,
                               x_test, y_test, ir_alpha=ir_alpha)
        acc_ft.append(a_ft)
        acc_fz.append(a_fz)
        log(f"  layer {li + 1}/{n_layers} ({name}): "
            f"finetuned {a_ft:.4f} vs frozen {a_fz:.4f}")
    return acc_ft, acc_fz
