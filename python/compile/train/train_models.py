"""Build-time model training + weight export.

Trains the paper's four models (CPU-budget-scaled) with noise-resilient
training and exports npz weight files the rust coordinator loads:

    python -m compile.train.train_models --model mnist --out ../artifacts

npz layout (matches rust models/loader.rs): `<layer>.w` [in, out],
`<layer>.b` [out]; LSTM cells prefixed `cell<i>.`; RBM keys `rbm.w`,
`rbm.a`, `rbm.b`.
"""

import argparse
import os

import numpy as np

from .. import data as D
from .. import model as M
from . import noise_train as NT


def export_npz(path, tensors):
    np.savez(path, **{k: np.asarray(v, np.float32) for k, v in tensors.items()})
    print(f"  wrote {path} ({len(tensors)} arrays)")


def train_mnist(out_dir, *, n_train=3000, epochs=10, noise_frac=0.1,
                width=8, seed=0):
    mdl = M.mnist_cnn7(width=width)
    x, y = D.load_or_generate("digits28", n_train, seed=seed)
    print(f"[mnist] training {mdl.name} on {n_train} digits...")
    params, hist = NT.train_classifier(mdl, x, y, noise_frac=noise_frac,
                                       epochs=epochs, lr=3e-3, seed=seed,
                                       log_every=1)
    xt, yt = D.load_or_generate("digits28", 500, seed=seed + 1)
    acc = NT.eval_float(mdl, params, xt, yt)
    print(f"[mnist] float accuracy: {acc:.4f}; final loss {hist[-1]:.4f}")
    tensors = {}
    for s in mdl.specs:
        tensors[f"{s.name}.w"] = params[s.name]["w"]
        tensors[f"{s.name}.b"] = params[s.name]["b"]
    export_npz(os.path.join(out_dir, "mnist_weights.npz"), tensors)
    return acc


def train_lstm(out_dir, *, n_train=1200, epochs=6, noise_frac=0.1,
               hidden=64, n_cells=4, seed=0):
    mdl = M.speech_lstm(hidden=hidden, n_cells=n_cells)
    x, y = D.load_or_generate("mfcc_cmds", n_train, seed=seed)
    xq = D.quantize_signed(x, 4) / 7.0  # train on the quantized grid
    print(f"[lstm] training {n_cells}-cell LSTM on {n_train} series...")
    params, hist = NT.train_classifier(mdl, xq, y, noise_frac=noise_frac,
                                       epochs=epochs, lr=3e-3, seed=seed,
                                       log_every=1)
    xt, yt = D.load_or_generate("mfcc_cmds", 400, seed=seed + 1)
    acc = NT.eval_float(mdl, params, D.quantize_signed(xt, 4) / 7.0, yt)
    print(f"[lstm] float accuracy: {acc:.4f}")
    tensors = {}
    for c in range(n_cells):
        tensors[f"cell{c}.wx.w"] = params[c]["wx"]["w"]
        tensors[f"cell{c}.wx.b"] = params[c]["wx"]["b"]
        tensors[f"cell{c}.wh.w"] = params[c]["wh"]["w"]
        tensors[f"cell{c}.wo.w"] = params[c]["wo"]["w"]
        tensors[f"cell{c}.wo.b"] = params[c]["wo"]["b"]
    export_npz(os.path.join(out_dir, "lstm_weights.npz"), tensors)
    return acc


def train_rbm(out_dir, *, n_train=2000, epochs=15, noise_frac=0.25, seed=0):
    rbm = M.RbmModel()
    imgs, labels = D.load_or_generate("digits28", n_train, seed=seed)
    v = (imgs.reshape(n_train, 784) > 0.5).astype(np.float32)
    onehot = np.eye(10, dtype=np.float32)[labels]
    v = np.concatenate([v, onehot], axis=1)  # 794 visible units
    print(f"[rbm] CD-1 training on {n_train} binarized digits...")
    params, hist = NT.train_rbm(rbm, v, epochs=epochs,
                                noise_frac=noise_frac, seed=seed, log_every=3)
    print(f"[rbm] final recon mse: {hist[-1]:.4f}")
    export_npz(os.path.join(out_dir, "rbm_weights.npz"),
               {"rbm.w": params["w"], "rbm.a": params["a"],
                "rbm.b": params["b"]})
    return hist[-1]


def train_cifar(out_dir, *, n_train=1500, epochs=8, noise_frac=0.1,
                width=8, blocks=1, seed=0):
    mdl = M.cifar_resnet(width=width, blocks_per_stage=blocks)
    x, y = D.load_or_generate("textures32", n_train, seed=seed)
    print(f"[cifar] training {len(mdl.specs)}-layer resnet on {n_train} "
          f"textures...")
    params, hist = NT.train_classifier(mdl, x, y, noise_frac=noise_frac,
                                       epochs=epochs, seed=seed, log_every=1)
    xt, yt = D.load_or_generate("textures32", 400, seed=seed + 1)
    acc = NT.eval_float(mdl, params, xt, yt)
    print(f"[cifar] float accuracy: {acc:.4f}")
    tensors = {}
    for s in mdl.specs:
        tensors[f"{s.name}.w"] = params[s.name]["w"]
        tensors[f"{s.name}.b"] = params[s.name]["b"]
    export_npz(os.path.join(out_dir, "cifar_weights.npz"), tensors)
    return acc


def train_mnist_noise_sweep(out_dir, *, n_train=2000, epochs=8,
                            levels=(0.0, 0.1, 0.2, 0.3), seed=0):
    """ED Fig. 6 models: one export per training-noise level.

    Writes mnist_weights_n{00,10,20,30}.npz plus mnist_weights_nonoise.npz
    (alias of the 0.0 level, used by the Fig. 3e ablation bench)."""
    mdl = M.mnist_cnn7(width=8)
    x, y = D.load_or_generate("digits28", n_train, seed=seed)
    xt, yt = D.load_or_generate("digits28", 400, seed=seed + 1)
    for nf in levels:
        print(f"[sweep] training at noise {nf:.2f}...")
        params, _ = NT.train_classifier(mdl, x, y, noise_frac=nf,
                                        epochs=epochs, lr=3e-3, seed=seed)
        acc = NT.eval_float(mdl, params, xt, yt)
        print(f"[sweep] noise {nf:.2f}: float acc {acc:.4f}")
        tensors = {}
        for s in mdl.specs:
            tensors[f"{s.name}.w"] = params[s.name]["w"]
            tensors[f"{s.name}.b"] = params[s.name]["b"]
        tag = f"n{int(round(nf * 100)):02d}"
        export_npz(os.path.join(out_dir, f"mnist_weights_{tag}.npz"), tensors)
        if nf == 0.0:
            export_npz(os.path.join(out_dir, "mnist_weights_nonoise.npz"),
                       tensors)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mnist",
                    choices=["mnist", "lstm", "rbm", "cifar", "all",
                             "noise-sweep"])
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=0, help="0 = default")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    kw = {"seed": args.seed}
    if args.epochs:
        kw["epochs"] = args.epochs
    if args.model in ("mnist", "all"):
        train_mnist(args.out, **kw)
    if args.model in ("lstm", "all"):
        train_lstm(args.out, **kw)
    if args.model in ("rbm", "all"):
        train_rbm(args.out, **kw)
    if args.model in ("cifar", "all"):
        train_cifar(args.out, **kw)
    if args.model == "noise-sweep":
        train_mnist_noise_sweep(args.out, seed=args.seed)


if __name__ == "__main__":
    main()
