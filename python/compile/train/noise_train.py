"""Noise-resilient NN training (paper Fig. 3c, Extended Data Fig. 6).

Instead of training with quantized weights, train with high-precision
floats while injecting noise whose distribution matches RRAM conductance
relaxation (Gaussian with sigma ~= 10% of each layer's w_max).  Training
at a *higher* noise than inference improves resilience (ED Fig. 6a-b);
RBMs do best at the highest injection level (ED Fig. 6c).

Everything here is build-time only.  Hand-rolled Adam -- optax is not in
this image.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as M


# --------------------------------------------------------------------------
# Hand-rolled Adam over nested dict/list pytrees
# --------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda x: x / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda x: x / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + eps),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


# --------------------------------------------------------------------------
# Classifier training (CNN / LSTM) with weight-noise injection
# --------------------------------------------------------------------------

def _to_jnp(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(p) if p is not None else None, params)


def train_classifier(mdl, x, y, *, noise_frac=0.1, epochs=4, batch=32,
                     lr=1e-3, seed=0, log_every=0, warmup_frac=0.4):
    """Train ``mdl`` (CnnModel or LstmModel) with noise-injected forward
    passes.  Noise injection is warmed up: the first ``warmup_frac`` of
    epochs train clean so the network first finds a solution, then noise
    hardens it (at CPU-budget model scale, 10-15% weight noise from step
    zero swamps the early gradient signal).  Returns (params, history)."""
    params = _to_jnp(mdl.init_params(seed))
    opt = adam_init(params)
    key = jax.random.PRNGKey(seed)
    n = x.shape[0]
    x = jnp.asarray(x)
    y = jnp.asarray(y)

    def loss_fn(p, xb, yb, k, nf):
        logits = mdl.train_forward(xb, p, noise_frac=nf, rng=k)
        return cross_entropy(logits, yb)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn),
                      static_argnames=("nf",))
    history = []
    steps_per_epoch = max(1, n // batch)
    rng = np.random.default_rng(seed)
    warmup = int(epochs * warmup_frac)
    for ep in range(epochs):
        nf = 0.0 if ep < warmup else noise_frac
        perm = rng.permutation(n)
        ep_loss = 0.0
        for s in range(steps_per_epoch):
            idx = perm[s * batch:(s + 1) * batch]
            key, sub = jax.random.split(key)
            loss, grads = grad_fn(params, x[idx], y[idx], sub, nf)
            params, opt = adam_step(params, grads, opt, lr=lr)
            ep_loss += float(loss)
        history.append(ep_loss / steps_per_epoch)
        if log_every and (ep % log_every == 0):
            print(f"  epoch {ep}: loss {history[-1]:.4f} (noise {nf})")
    return params, history


def eval_float(mdl, params, x, y, batch=64):
    """Noise-free float accuracy (the paper's software baseline)."""
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = mdl.train_forward(jnp.asarray(x[i:i + batch]), params)
        correct += int(jnp.sum(jnp.argmax(logits, 1) == y[i:i + batch]))
    return correct / x.shape[0]


def eval_noisy(mdl, params, x, y, noise_frac, seed=0, batch=64):
    """Accuracy with inference-time weight noise (ED Fig. 6 x-axis)."""
    key = jax.random.PRNGKey(seed + 999)
    correct = 0
    for i in range(0, x.shape[0], batch):
        key, sub = jax.random.split(key)
        logits = mdl.train_forward(jnp.asarray(x[i:i + batch]), params,
                                   noise_frac=noise_frac, rng=sub)
        correct += int(jnp.sum(jnp.argmax(logits, 1) == y[i:i + batch]))
    return correct / x.shape[0]


# --------------------------------------------------------------------------
# Model-driven calibration (paper Fig. 3b): choose per-layer requant shifts
# --------------------------------------------------------------------------

def calibrate_shifts(mdl, chip_params, x_sample, pctile=99.0):
    """Run training-set data through the chip-mode network layer by layer
    and pick each layer's requantization shift so the given percentile of
    the pre-activation lands at the top of the next layer's input range.

    This is the paper's "model-driven chip calibration": using data that
    matches the test-time distribution is essential (ED Fig. 5).
    """
    shifts = {}
    x = jnp.asarray(x_sample, jnp.float32)
    for i, s in enumerate(mdl.specs):
        p = chip_params[s.name]
        last = i == len(mdl.specs) - 1
        next_bits = mdl.specs[i + 1].input_bits if not last else 4
        if s.kind == "conv":
            cols = M.im2col(x, s.kh, s.kw, s.stride, s.padding)
            b, ho, wo, r = cols.shape
            y = M.cim_linear(cols.reshape(b * ho * wo, r), p["g_pos"],
                             p["g_neg"], s, p["w_max"], p["n_bias_rows"],
                             use_pallas=False)
            y = y.reshape(b, ho, wo, s.out_features)
            y = M.maxpool2(y, s.pool)
        else:
            y = M.cim_linear(x.reshape(x.shape[0], -1), p["g_pos"],
                             p["g_neg"], s, p["w_max"], p["n_bias_rows"],
                             use_pallas=False)
            if last:
                shifts[s.name] = 0.0
                break
        top = float(np.percentile(np.asarray(jnp.maximum(y, 0.0)), pctile))
        q_max = 2 ** (next_bits - 1) - 1   # unsigned act in signed range
        shift = max(0.0, float(np.ceil(np.log2(max(top, 1e-6) / q_max))))
        shifts[s.name] = shift
        x = M.requantize(y, shift, next_bits - 1, signed=False)
    return shifts


def eval_chip(mdl, chip_params, shifts, x, y, batch=32, ir_alpha=0.0,
              use_pallas=False):
    """Chip-mode (integer pipeline) accuracy."""
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = mdl.chip_forward(jnp.asarray(x[i:i + batch]), chip_params,
                                  shifts, use_pallas=use_pallas,
                                  ir_alpha=ir_alpha)
        correct += int(jnp.sum(jnp.argmax(logits, 1) == y[i:i + batch]))
    return correct / x.shape[0]


# --------------------------------------------------------------------------
# Programming noise (device relaxation) applied to chip params
# --------------------------------------------------------------------------

def apply_relaxation(chip_params, sigma_us=2.0, seed=0, g_min=1.0,
                     g_max=41.0):
    """Gaussian conductance relaxation on every programmed cell (paper ED
    Fig. 3d; sigma ~2 uS after 3 write-verify iterations).  Mirrors
    rust/src/device/rram.rs::relax()."""
    rng = np.random.default_rng(seed)
    out = {}
    items = chip_params.items() if isinstance(chip_params, dict) else \
        enumerate(chip_params)
    for k, p in items:
        if isinstance(p, dict) and "g_pos" in p:
            q = dict(p)
            for g in ("g_pos", "g_neg"):
                noisy = np.asarray(p[g]) + rng.normal(0, sigma_us,
                                                      np.shape(p[g]))
                q[g] = np.clip(noisy, g_min, g_max).astype(np.float32)
            out[k] = q
        elif isinstance(p, dict):
            out[k] = apply_relaxation(p, sigma_us, seed + 1, g_min, g_max)
        else:
            out[k] = p
    if isinstance(chip_params, list):
        return [out[i] for i in range(len(out))]
    return out


# --------------------------------------------------------------------------
# RBM training: contrastive divergence (CD-1)
# --------------------------------------------------------------------------

def train_rbm(rbm, v_data, *, epochs=12, batch=32, lr=0.05, seed=0,
              noise_frac=0.0, log_every=0):
    """CD-1 with optional weight-noise injection on the positive phase
    (ED Fig. 6c: RBMs benefit from high injection levels)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    p = rbm.init_params(seed)
    w = jnp.asarray(p["w"])
    a = jnp.asarray(p["a"])
    b = jnp.asarray(p["b"])
    n = v_data.shape[0]
    v_data = jnp.asarray(v_data, jnp.float32)

    @jax.jit
    def cd1(w, a, b, v0, k):
        k1, k2, k3 = jax.random.split(k, 3)
        ph0 = jax.nn.sigmoid(v0 @ w + b)
        h0 = (jax.random.uniform(k1, ph0.shape) < ph0).astype(jnp.float32)
        pv1 = jax.nn.sigmoid(h0 @ w.T + a)
        v1 = (jax.random.uniform(k2, pv1.shape) < pv1).astype(jnp.float32)
        ph1 = jax.nn.sigmoid(v1 @ w + b)
        bsz = v0.shape[0]
        dw = (v0.T @ ph0 - v1.T @ ph1) / bsz
        da = jnp.mean(v0 - v1, axis=0)
        db = jnp.mean(ph0 - ph1, axis=0)
        return dw, da, db

    history = []
    for ep in range(epochs):
        perm = rng.permutation(n)
        err = 0.0
        for s in range(max(1, n // batch)):
            idx = perm[s * batch:(s + 1) * batch]
            key, sub, nz = jax.random.split(key, 3)
            w_eff = w
            if noise_frac > 0.0:
                w_eff = w + jax.random.normal(nz, w.shape) * \
                    (noise_frac * jnp.max(jnp.abs(w)))
            dw, da, db = cd1(w_eff, a, b, v_data[idx], sub)
            w = w + lr * dw
            a = a + lr * da
            b = b + lr * db
        # epoch reconstruction error on a slice
        ph = jax.nn.sigmoid(v_data[:256] @ w + b)
        pv = jax.nn.sigmoid(ph @ w.T + a)
        err = float(jnp.mean((pv - v_data[:256]) ** 2))
        history.append(err)
        if log_every and ep % log_every == 0:
            print(f"  rbm epoch {ep}: recon mse {err:.4f}")
    return {"w": np.asarray(w), "a": np.asarray(a), "b": np.asarray(b)}, \
        history
