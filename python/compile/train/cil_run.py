"""Run the chip-in-the-loop progressive fine-tuning experiment (Fig. 3f)
and write the accuracy trajectories to artifacts/cil_results.json for the
rust bench `fig3f_cil` to tabulate.

    python -m compile.train.cil_run [--train N] [--test N] [--epochs E]
"""

import argparse
import json
import os

import numpy as np

from .. import data as D
from .. import model as M
from . import cil
from . import noise_train as NT


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", type=int, default=600)
    ap.add_argument("--test", type=int, default=300)
    ap.add_argument("--epochs", type=int, default=2,
                    help="fine-tune epochs per programmed layer")
    ap.add_argument("--base-epochs", type=int, default=8)
    ap.add_argument("--noise", type=float, default=0.15)
    ap.add_argument("--ir-alpha", type=float, default=0.6)
    ap.add_argument("--relax-sigma", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts/cil_results.json")
    args = ap.parse_args()

    mdl = M.mnist_cnn7(width=8)
    x, y = D.load_or_generate("digits28", args.train, seed=args.seed)
    xt, yt = D.load_or_generate("digits28", args.test, seed=args.seed + 1)
    print(f"[cil] training baseline on {args.train} digits...")
    params, _ = NT.train_classifier(mdl, x, y, noise_frac=args.noise,
                                    epochs=args.base_epochs, lr=3e-3,
                                    seed=args.seed, log_every=2)
    base_acc = NT.eval_float(mdl, params, xt, yt)
    print(f"[cil] software float accuracy: {base_acc:.4f}")

    in_bits = mdl.specs[0].input_bits - 1
    xq = D.quantize_unsigned(x, in_bits)
    xtq = D.quantize_unsigned(xt, in_bits)

    print(f"[cil] progressive fine-tuning (ir_alpha={args.ir_alpha}, "
          f"relax={args.relax_sigma} uS)...")
    acc_ft, acc_fz = cil.progressive_finetune(
        mdl, params, xq, np.asarray(y), xtq, np.asarray(yt),
        relax_sigma=args.relax_sigma, ir_alpha=args.ir_alpha,
        epochs=args.epochs, noise_frac=args.noise, seed=args.seed)

    result = {
        "model": mdl.name,
        "layers": [s.name for s in mdl.specs],
        "software_float_acc": base_acc,
        "acc_with_finetune": acc_ft,
        "acc_without_finetune": acc_fz,
        "final_gain": acc_ft[-1] - acc_fz[-1],
        "params": {
            "train": args.train, "test": args.test,
            "ft_epochs": args.epochs, "noise": args.noise,
            "ir_alpha": args.ir_alpha, "relax_sigma": args.relax_sigma,
        },
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[cil] final: with-ft {acc_ft[-1]:.4f} vs frozen {acc_fz[-1]:.4f} "
          f"(gain {result['final_gain'] * 100:+.2f}%)")
    print(f"[cil] wrote {args.out}")


if __name__ == "__main__":
    main()
