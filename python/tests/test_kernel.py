"""L1 correctness: Pallas CIM-MVM kernel vs the pure-jnp oracle.

The Pallas kernel must be *bit-exact* against ``ref.py`` across shapes,
bit-precisions and activation functions -- it is the same arithmetic
expressed as the chip's weight-stationary bit-serial schedule.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.cimcfg import CimConfig
from compile.kernels import mvm, ref

RNG = np.random.default_rng(1234)


def make_case(rows, cols, batch, input_bits, w_seed=0):
    rng = np.random.default_rng(w_seed)
    w = rng.normal(size=(rows, cols)).astype(np.float32)
    cfg0 = CimConfig(rows=rows, cols=cols, input_bits=input_bits)
    m = cfg0.in_mag_max
    x = rng.integers(-m, m + 1, size=(batch, rows)).astype(np.float32)
    return w, x


def run_both(w, x, cfg, noise=None):
    g_pos, g_neg = ref.encode_differential(w, cfg.g_max_us, cfg.g_min_us)
    a = np.asarray(ref.cim_mvm_ref(x, g_pos, g_neg, cfg, noise=noise))
    b = np.asarray(mvm.cim_mvm_pallas(x, g_pos, g_neg, cfg, noise=noise))
    return a, b


def assert_quantized_match(a, b, max_mismatch_frac=0.02):
    """Kernel vs oracle contract: identical up to floor-boundary ties.

    The kernel accumulates the MVM bit-plane by bit-plane (the chip's
    schedule) while the oracle does one matmul; f32 non-associativity can
    land the settled voltage on the other side of an ADC step boundary.
    Outputs must agree within 1 quantum and be exactly equal almost
    everywhere.
    """
    assert np.all(np.abs(a - b) <= 1.0 + 1e-6), np.max(np.abs(a - b))
    if a.size >= 32:
        assert np.mean(a != b) <= max_mismatch_frac


# --------------------------------------------------------------------------
# Exhaustive-ish fixed cases
# --------------------------------------------------------------------------

@pytest.mark.parametrize("input_bits", [1, 2, 3, 4, 5, 6])
@pytest.mark.parametrize("output_bits", [1, 2, 4, 8])
def test_bit_precision_grid(input_bits, output_bits):
    """Paper: 1-6 bit inputs x 1-8 bit outputs all supported."""
    w, x = make_case(32, 16, 8, input_bits, w_seed=input_bits)
    cfg = CimConfig(rows=32, cols=16, input_bits=input_bits,
                    output_bits=output_bits)
    a, b = run_both(w, x, cfg)
    assert_quantized_match(a, b)
    assert np.max(np.abs(a)) <= cfg.out_mag_max


@pytest.mark.parametrize("act", ["none", "relu", "tanh", "sigmoid"])
def test_activations(act):
    w, x = make_case(24, 12, 6, 4)
    cfg = CimConfig(rows=24, cols=12, input_bits=4, output_bits=8,
                    activation=act, adc_lsb_frac=1 / 256)
    a, b = run_both(w, x, cfg)
    assert_quantized_match(a, b)
    if act == "relu":
        assert np.min(a) >= 0.0
    if act == "sigmoid":
        assert np.min(a) >= 0.0 and np.max(a) <= cfg.out_mag_max


def test_stochastic_binary_outputs():
    w, x = make_case(16, 16, 4, 2)
    cfg = CimConfig(rows=16, cols=16, input_bits=2, output_bits=1,
                    activation="stochastic")
    noise = RNG.normal(scale=0.01, size=(4, 16)).astype(np.float32)
    a, b = run_both(w, x, cfg, noise=noise)
    assert_quantized_match(a, b)
    assert set(np.unique(a)).issubset({0.0, 1.0})


def test_ir_drop_reduces_magnitude():
    """Non-ideality (i)-(iii): IR drop shrinks the settled voltage."""
    w, x = make_case(64, 8, 4, 4)
    base = CimConfig(rows=64, cols=8, input_bits=4, ir_alpha=0.0)
    ir = CimConfig(rows=64, cols=8, input_bits=4, ir_alpha=0.5)
    g_pos, g_neg = ref.encode_differential(w, base.g_max_us, base.g_min_us)
    v0 = np.abs(np.asarray(ref.settle_voltage(x, g_pos, g_neg, base)))
    v1 = np.abs(np.asarray(ref.settle_voltage(x, g_pos, g_neg, ir)))
    assert np.all(v1 <= v0 + 1e-9)
    # pallas path agrees under IR drop too
    a, b = run_both(w, x, ir)
    assert_quantized_match(a, b)


def test_voltage_mode_normalization():
    """Fig 2i: scaling all weights by a constant leaves outputs unchanged
    (the conductance-weighted average cancels the scale)."""
    w, x = make_case(32, 8, 4, 4)
    cfg = CimConfig(rows=32, cols=8, input_bits=4)
    g_pos, g_neg = ref.encode_differential(w, cfg.g_max_us, cfg.g_min_us)
    v1 = np.asarray(ref.settle_voltage(x, g_pos, g_neg, cfg))
    v2 = np.asarray(ref.settle_voltage(x, 0.5 * g_pos, 0.5 * g_neg, cfg))
    np.testing.assert_allclose(v1, v2, rtol=1e-6)


def test_mvm_scale_recovers_linear_product():
    """y_int * mvm_scale approximates x @ w (paper's digital de-normalization)."""
    rows, cols = 64, 16
    rng = np.random.default_rng(7)
    w = rng.normal(size=(rows, cols)).astype(np.float32)
    x = rng.integers(-7, 8, size=(16, rows)).astype(np.float32)
    cfg = CimConfig(rows=rows, cols=cols, input_bits=4, output_bits=8,
                    adc_lsb_frac=1 / 64)
    w_max = float(np.max(np.abs(w)))
    g_pos, g_neg = ref.encode_differential(w, cfg.g_max_us, cfg.g_min_us)
    y = np.asarray(ref.cim_mvm_ref(x, g_pos, g_neg, cfg))
    scale = np.asarray(ref.mvm_scale(g_pos, g_neg, cfg, w_max))
    approx = y * scale
    exact = x @ w
    # Error bounded by ADC LSB (~= scale, in weight units) + g_min clamp.
    mask = np.abs(y) < cfg.out_mag_max        # unclipped outputs only
    err = np.abs(approx - exact)[mask]
    ref_mag = np.maximum(np.abs(exact)[mask], 1.0)
    # Median output is ADC-accurate; aggregate error (ADC floor bias +
    # g_min clamp zeroing weights below w_max/40) stays ~10% of signal.
    assert np.median(err / ref_mag) < 0.15
    assert np.mean(err) / np.mean(np.abs(exact)) < 0.15


def test_bit_plane_reconstruction():
    x = RNG.integers(-31, 32, size=(5, 9)).astype(np.float32)
    planes = ref.bit_planes(x, 6)
    assert planes.shape == (5, 5, 9)
    weights = 2.0 ** np.arange(4, -1, -1)
    recon = np.einsum("p,pbr->br", weights, planes)
    np.testing.assert_array_equal(recon, x)


# --------------------------------------------------------------------------
# Hypothesis sweeps: shapes / bits / seeds
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 48),
    cols=st.integers(1, 48),
    batch=st.integers(1, 8),
    input_bits=st.integers(1, 6),
    output_bits=st.integers(1, 8),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_kernel_matches_ref_hypothesis(rows, cols, batch, input_bits,
                                       output_bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(rows, cols)).astype(np.float32)
    cfg = CimConfig(rows=rows, cols=cols, input_bits=input_bits,
                    output_bits=output_bits)
    m = cfg.in_mag_max
    x = rng.integers(-m, m + 1, size=(batch, rows)).astype(np.float32)
    a, b = run_both(w, x, cfg)
    assert_quantized_match(a, b)


@settings(max_examples=20, deadline=None)
@given(
    act=st.sampled_from(["none", "relu", "tanh", "sigmoid"]),
    lsb=st.sampled_from([1 / 32, 1 / 64, 1 / 128, 1 / 256]),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_kernel_activation_hypothesis(act, lsb, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(20, 10)).astype(np.float32)
    cfg = CimConfig(rows=20, cols=10, input_bits=4, output_bits=8,
                    activation=act, adc_lsb_frac=lsb)
    x = rng.integers(-7, 8, size=(3, 20)).astype(np.float32)
    a, b = run_both(w, x, cfg)
    assert_quantized_match(a, b)


# --------------------------------------------------------------------------
# ADC invariants
# --------------------------------------------------------------------------

def test_adc_monotone_in_voltage():
    cfg = CimConfig()
    v = np.linspace(-0.2, 0.2, 801).astype(np.float32)
    y = np.asarray(ref.adc_quantize(v, cfg))
    assert np.all(np.diff(y) >= 0.0)


def test_adc_zero_is_zero():
    cfg = CimConfig()
    assert float(np.asarray(ref.adc_quantize(np.zeros(4, np.float32), cfg))[0]) == 0.0


def test_encode_differential_polarity():
    w = np.array([[1.0, -1.0, 0.0]], np.float32)
    gp, gn = ref.encode_differential(w, 40.0, 1.0, w_max=1.0)
    np.testing.assert_allclose(np.asarray(gp), [[40.0, 1.0, 1.0]])
    np.testing.assert_allclose(np.asarray(gn), [[1.0, 40.0, 1.0]])
