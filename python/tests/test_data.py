"""Dataset substrate tests (mirrored by rust io/datasets.rs tests)."""

import numpy as np

from compile import data as D


def test_digits_shapes_and_range():
    x, y = D.digits28(30, seed=1)
    assert x.shape == (30, 28, 28, 1)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))
    # images carry ink
    assert x.sum(axis=(1, 2, 3)).min() > 5.0


def test_digits_class_coverage_and_determinism():
    x1, y1 = D.digits28(200, seed=2)
    x2, y2 = D.digits28(200, seed=2)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert len(np.unique(y1)) == 10


def test_textures_classes_distinct():
    x, y = D.textures32(40, seed=3, noise=0.0)
    assert x.shape == (40, 32, 32, 3)
    # mean image per class differs
    means = {}
    for c in np.unique(y):
        means[c] = x[y == c].mean(axis=0)
    classes = sorted(means)
    if len(classes) >= 2:
        d = np.abs(means[classes[0]] - means[classes[1]]).sum()
        assert d > 1.0


def test_mfcc_shapes_and_normalization():
    x, y = D.mfcc_cmds(50, seed=4)
    assert x.shape == (50, 50, 40)
    assert abs(float(x.mean())) < 0.05
    assert abs(float(x.std()) - 1.0) < 0.05
    assert set(np.unique(y)).issubset(set(range(12)))


def test_quantizers():
    x = np.array([0.0, 0.5, 1.0], np.float32)
    q = D.quantize_unsigned(x, 3)
    assert q.tolist() == [0.0, 4.0, 7.0]
    z = np.random.default_rng(0).normal(size=1000).astype(np.float32)
    qs = D.quantize_signed(z, 4)
    assert qs.max() <= 7 and qs.min() >= -7
    assert len(np.unique(qs)) > 5


def test_load_or_generate_fallback(tmp_path):
    x, y = D.load_or_generate("digits28", 10, seed=5,
                              data_dir=str(tmp_path))
    assert x.shape[0] == 10
    # with a file present, the file wins
    np.savez(tmp_path / "digits28.npz",
             x=np.zeros((4, 28, 28, 1), np.float32),
             y=np.arange(4))
    x2, y2 = D.load_or_generate("digits28", 3, seed=5,
                                data_dir=str(tmp_path))
    assert x2.shape == (3, 28, 28, 1)
    assert x2.sum() == 0.0
