"""L2 model-layer tests: shapes, quantization pipeline, mapping helpers."""

import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile.cimcfg import CimConfig


def test_mnist_graph_shapes():
    mdl = M.mnist_cnn7(8)
    assert len(mdl.specs) == 7
    assert mdl.specs[0].in_features == 9
    assert mdl.specs[0].input_bits == 5       # 4-b unsigned input image
    assert mdl.specs[1].input_bits == 4       # 3-b unsigned activations
    assert mdl.specs[-1].in_features == 3 * 3 * 32


def test_cifar_graph_is_resnet_shaped():
    mdl = M.cifar_resnet(8, 3)
    assert len(mdl.specs) == 20
    assert mdl.specs[-1].out_features == 10


def test_row_segments_cover():
    for n in [1, 100, 128, 129, 300, 794]:
        segs = M.row_segments(n)
        assert segs[0][0] == 0
        assert segs[-1][1] == n
        for (a, b), (c, _) in zip(segs, segs[1:]):
            assert b == c
        assert all(b - a <= 128 for a, b in segs)


def test_bias_rows_scaling():
    # bias B times the weight range needs B rows (paper Methods)
    w = np.ones((4, 2), np.float32)
    b = np.array([14.0, -14.0], np.float32)
    aug, nb = M.augment_with_bias(w, b, in_mag=7)
    assert nb == 2
    assert aug.shape == (6, 2)
    # driven at in_mag the bias rows reconstruct b
    contrib = aug[4:, :].sum(axis=0) * 7
    np.testing.assert_allclose(contrib, b, rtol=1e-6)


def test_cim_linear_matches_dense_product():
    rng = np.random.default_rng(0)
    spec = M.CimLayerSpec(name="l", kind="dense", in_features=32,
                          out_features=8, input_bits=4, activation="none")
    w = rng.normal(size=(32, 8)).astype(np.float32)
    b = rng.normal(size=8).astype(np.float32) * 0.1
    aug, nb = M.augment_with_bias(w, b, 7)
    gp, gn, w_max = M.layer_conductances(aug, spec.g_max_us)
    x = rng.integers(-3, 4, size=(4, 32)).astype(np.float32)
    y = np.asarray(M.cim_linear(x, gp, gn, spec, w_max, nb,
                                use_pallas=False))
    want = x @ w + 7 * np.tile(b / 7, (4, 1))  # bias rows at full drive
    mask = np.abs(y) > 0
    err = np.abs(y - want)
    assert np.median(err) < 0.35 * np.median(np.abs(want)) + 0.5
    assert mask.any()


def test_requantize_halfrange():
    y = np.array([0.0, 3.9, 8.0, 100.0, -5.0])
    q = np.asarray(M.requantize(y, shift=0.0, bits=3, signed=False))
    assert q.tolist() == [0.0, 3.0, 7.0, 7.0, 0.0]


def test_im2col_matches_manual():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(1, 5, 5, 2)).astype(np.float32)
    cols = np.asarray(M.im2col(x, 3, 3, 1, "SAME"))
    assert cols.shape == (1, 5, 5, 18)
    # centre pixel of patch (2,2) = x[2,2,:] at kernel position (1,1)
    patch = cols[0, 2, 2].reshape(9, 2)
    np.testing.assert_allclose(patch[4], x[0, 2, 2])
    # corner patch zero-padded
    patch = cols[0, 0, 0].reshape(9, 2)
    np.testing.assert_allclose(patch[0], 0.0)


def test_chip_forward_runs_and_is_deterministic():
    mdl = M.mnist_cnn7(4)
    params = mdl.init_params(0)
    chip = mdl.map_to_chip(params)
    shifts = {s.name: 1.0 for s in mdl.specs}
    x, _ = D.digits28(2, seed=3)
    xq = D.quantize_unsigned(x, 4)
    a = np.asarray(mdl.chip_forward(xq, chip, shifts, use_pallas=False))
    b = np.asarray(mdl.chip_forward(xq, chip, shifts, use_pallas=False))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 10)


def test_lstm_chip_forward_shapes():
    mdl = M.speech_lstm(hidden=16, n_cells=2)
    mdl_small = M.LstmModel("t", n_cells=2, hidden=16, time_steps=5)
    params = mdl_small.init_params(0)
    chip = mdl_small.map_to_chip(params)
    x = np.zeros((3, 5, 40), np.float32)
    x[:, :, 10] = 3.0
    logits = np.asarray(mdl_small.chip_forward(x, chip, use_pallas=False))
    assert logits.shape == (3, 12)


def test_rbm_recover_resets_known_pixels():
    import jax
    rbm = M.RbmModel()
    params = rbm.init_params(0)
    chip = rbm.map_to_chip(params)
    v0 = np.zeros((2, 794), np.float32)
    v0[:, :50] = 1.0
    known = np.ones((2, 794), np.float32)
    known[:, 100:200] = 0.0
    out = np.asarray(rbm.recover(v0, known, chip, jax.random.PRNGKey(0),
                                 n_cycles=2, use_pallas=False))
    # known pixels unchanged
    np.testing.assert_array_equal(out[:, :50], v0[:, :50])
    assert set(np.unique(out)).issubset({0.0, 1.0})


def test_fake_quant_range_tracks_batch():
    import jax.numpy as jnp
    body = np.linspace(0.0, 2.0, 200, dtype=np.float32)
    y = jnp.asarray(np.concatenate([body, [100.0]]))
    q = np.asarray(M.fake_quant_unsigned(y, 3))
    assert q.min() >= 0.0
    # the lone outlier is clipped toward the batch's mean+3sigma alpha
    assert q[-1] < 50.0
    # in-range values survive quantization roughly unchanged
    assert abs(float(q[100]) - float(body[100])) < 1.5
