"""Training-path tests: noise-resilient training, calibration, CIL
machinery (small scale -- correctness of the plumbing, not accuracy)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile.train import cil
from compile.train import noise_train as NT


def small_model():
    return M.mnist_cnn7(width=4)


def test_adam_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = NT.adam_init(params)
    for _ in range(300):
        grads = {"w": 2.0 * params["w"]}
        params, opt = NT.adam_step(params, grads, opt, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_training_reduces_loss():
    mdl = small_model()
    x, y = D.digits28(120, seed=0)
    params, hist = NT.train_classifier(mdl, x, y, noise_frac=0.0, epochs=3,
                                       lr=3e-3, seed=0)
    assert hist[-1] < hist[0]


def test_noise_injection_changes_forward():
    mdl = small_model()
    params = NT._to_jnp(mdl.init_params(0))
    x, _ = D.digits28(2, seed=1)
    clean = mdl.train_forward(jnp.asarray(x), params)
    noisy = mdl.train_forward(jnp.asarray(x), params, noise_frac=0.2,
                              rng=jax.random.PRNGKey(0))
    assert not np.allclose(np.asarray(clean), np.asarray(noisy))


def test_calibrate_shifts_keep_activations_in_range():
    mdl = small_model()
    params = mdl.init_params(0)
    chip = mdl.map_to_chip(params)
    x, _ = D.digits28(4, seed=2)
    xq = D.quantize_unsigned(x, 4)
    shifts = NT.calibrate_shifts(mdl, chip, xq)
    assert set(shifts) == {s.name for s in mdl.specs}
    assert all(v >= 0 for v in shifts.values())


def test_apply_relaxation_clips_and_perturbs():
    chip = {"l": {"g_pos": np.full((4, 4), 20.0, np.float32),
                  "g_neg": np.full((4, 4), 1.0, np.float32),
                  "w_max": 1.0, "n_bias_rows": 0}}
    out = NT.apply_relaxation(chip, sigma_us=2.0, seed=1)
    assert not np.allclose(out["l"]["g_pos"], chip["l"]["g_pos"])
    assert out["l"]["g_pos"].min() >= 1.0
    assert out["l"]["g_pos"].max() <= 41.0


def test_rbm_cd1_improves_reconstruction():
    rbm = M.RbmModel(n_visible=794, n_hidden=32)
    imgs, labels = D.digits28(300, seed=3)
    v = (imgs.reshape(300, 784) > 0.5).astype(np.float32)
    v = np.concatenate([v, np.eye(10, dtype=np.float32)[labels]], axis=1)
    _, hist = NT.train_rbm(rbm, v, epochs=4, seed=0)
    assert hist[-1] < hist[0]


def test_cil_hybrid_accuracy_machinery():
    mdl = small_model()
    x, y = D.digits28(40, seed=4)
    params, _ = NT.train_classifier(mdl, x, y, noise_frac=0.0, epochs=2,
                                    lr=3e-3, seed=0)
    xq = D.quantize_unsigned(x, 4)
    chip = mdl.map_to_chip(
        jax.tree_util.tree_map(
            lambda p: np.asarray(p) if p is not None else None, params))
    shifts = NT.calibrate_shifts(mdl, chip, xq[:8])
    acc0 = cil.hybrid_accuracy(mdl, params, chip, shifts, 0,
                               xq, np.asarray(y), ir_alpha=0.0)
    acc_all = cil.hybrid_accuracy(mdl, params, chip, shifts, len(mdl.specs),
                                  xq, np.asarray(y), ir_alpha=0.0)
    assert 0.0 <= acc0 <= 1.0
    assert 0.0 <= acc_all <= 1.0


def test_finetune_suffix_freezes_programmed_layers():
    mdl = small_model()
    x, y = D.digits28(24, seed=5)
    params, _ = NT.train_classifier(mdl, x, y, noise_frac=0.0, epochs=1,
                                    lr=3e-3, seed=0)
    # synthetic conv1-output features (what the chip would measure):
    # integer activations in the 3-b unsigned range, conv1 channel count
    rng = np.random.default_rng(0)
    feats = rng.integers(0, 8, size=(24, 28, 28, 4)).astype(np.float32)
    before = np.asarray(params["conv1"]["w"]).copy()
    tuned = cil.finetune_suffix(mdl, params, jnp.asarray(feats),
                                jnp.asarray(y), 1, epochs=1, lr=1e-3,
                                noise_frac=0.0, seed=0)
    np.testing.assert_array_equal(before, np.asarray(tuned["conv1"]["w"]))
    assert not np.allclose(np.asarray(params["fc"]["w"]),
                           np.asarray(tuned["fc"]["w"]))
